"""Continuous-batching serving engine with a device-resident decode loop.

The Ironwood-era premise: serving is a first-class supercomputer workload,
so the engine is built like one — a stable deterministic datapath (the
paged pool + chunked decode scan) that rapidly-changing workload tricks
plug into without changing the architecture:

  * **Continuous batching** (scheduler.py): requests are admitted into
    free batch slots and drained *mid-decode*; finished or preempted
    slots refill without flushing the batch.
  * **Block/paged KV cache** (kv_cache.py): pure-attention stacks store
    KV in a shared page pool addressed through a device page table, with
    int8 page quantization as the HBM lever — quantized pages stream
    natively through the Pallas kernels (in-VMEM dequant via page-
    aligned scale pages); other families (Mamba/RWKV/enc-dec) use
    per-slot dense ring/state caches behind the same interface.
  * **Chunked prefill**: cold prompts prefill in fixed-size spans
    (``prefill_chunk``) through the same span-decode datapath as
    cached-suffix prefill — one compiled program family for every
    prompt length, prefill compute scaling with the prompt instead of
    the window. Hybrid (attention + state) stacks get the same through
    the *dense* span path (``api.decode_span_fn``): right-aligned
    chunks at absolute positions, recurrent state threading through.
  * **Prefix caching** (kv_cache.py): full prompt pages are content-
    addressed in a global LRU index; admissions that hit share the cached
    pages by reference (copy-on-write protected) and prefill only the
    prompt *suffix* via the span-decode path — the system-prompt /
    few-shot-template traffic shape served at near-zero prefill cost.
  * **Self-speculative decoding** (``draft_k > 0``): an n-gram prompt-
    lookup drafter (no second model) proposes ``draft_k`` tokens from a
    device-resident token history; one batched span decode scores the
    whole draft, the longest prefix matching the model's own greedy
    targets is accepted, and rollback of rejected tokens is a pure
    position rewind — pages are append-only, so un-accepted k/v simply
    stay beyond the validity frontier until overwritten (the paper's
    checkpoint-replay framing applied to decode).
  * **Device-resident decode**: the hot loop is a ``lax.scan`` of
    ``chunk`` decode steps compiled once — draft, verify, sample,
    EOS/budget masking, cache write and position bookkeeping all stay on
    device. The host syncs once per *chunk* (not per token) to drain
    emitted tokens and make scheduling decisions.

The legacy single-batch ``generate()`` survives as a thin wrapper that
submits one request per batch row; ``generate_pertoken()`` keeps the old
one-jit-call-per-token loop as the benchmark baseline. See
docs/serving.md for lifecycle diagrams of all three subsystems.
"""

from __future__ import annotations

import dataclasses
import logging
import math
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hwspec
from repro.core.topology import Torus
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.config import ModelConfig
from repro.models.params import axes_tree
from repro.obs.metrics import (CounterDict, MetricsRegistry,
                               QUEUE_WAIT_BUCKETS_STEPS)
from repro.obs.steptrace import StepTrace
from repro.obs.trace import SpanTracer
from repro.serve.kv_cache import DenseKVCache, PagedKVCache
from repro.serve.scheduler import (ContinuousBatchingScheduler,
                                   PrefillWorkerPool, Request)
from repro.sharding.axes import (AxisRules, RULE_SETS, logical_constraint,
                                 summarize_dropped, tree_shardings)

Array = jax.Array
PyTree = Any

PAD_TOKEN = -1  # emitted by finished slots inside a chunk

log = logging.getLogger(__name__)

# modeled one-way software+wire latency of the prefill->decode handoff
_LINK_LATENCY_S = {"ici": 1.0e-6, "dcn": 50.0e-6}
# DCN-class bandwidth as a fraction of one ICI link direction (the paper's
# cross-pod federation rides data-center network, not ICI)
_DCN_LINK_FRACTION = 0.25


class PageTransferModel:
    """Modeled prefill->decode KV-page handoff for disaggregated serving.

    The two roles are modeled as slices of the same generation joined by a
    2-ring (one hop): an "ici" link for intra-pod disaggregation, or a
    DCN-class path (lower bandwidth, higher latency) for the paper's
    cross-pod federation. Transfer time = link latency + bytes / the
    ring's bisection bandwidth (core/topology.py), quantized to decode
    chunk boundaries against an HBM-roofline estimate of boundary time —
    so short prompts hide in one boundary while long cold prompts stall
    their slot for several."""

    def __init__(self, *, page_bytes: int, chunk: int, resident_bytes: int,
                 hw: str = "tpu_v5e", link: str = "ici"):
        if link not in _LINK_LATENCY_S:
            raise ValueError(
                f"transfer link must be one of {sorted(_LINK_LATENCY_S)}, "
                f"got {link!r}")
        spec = hwspec.get(hw)
        gbps = spec.ici_link_gbps * (1.0 if link == "ici"
                                     else _DCN_LINK_FRACTION)
        self.link = link
        self.torus = Torus(dims=(2,), link_gbps=gbps)
        self.latency_s = _LINK_LATENCY_S[link]
        self.page_bytes = page_bytes
        # decode boundary walltime: ``chunk`` steps, each streaming the
        # resident KV working set once (memory-bound decode roofline)
        self.boundary_s = chunk * resident_bytes / (spec.hbm_gbps * 1e9)

    def transfer_s(self, n_pages: int) -> float:
        bw = self.torus.bisection_gbps() * 1e9  # bytes/s across the hop
        return self.latency_s + n_pages * self.page_bytes / bw

    def delay_boundaries(self, n_pages: int) -> int:
        """Whole decode boundaries the pages are in flight (>= 1: a
        handoff is never visible inside the boundary that issued it)."""
        return max(1, math.ceil(self.transfer_s(max(1, n_pages))
                                / self.boundary_s))


@dataclasses.dataclass
class ServeEngine:
    """``window``: max total tokens per request (prompt + generated).

    ``draft_k``: speculative draft length per decode step (0 disables;
    requires the paged backend). ``prefix_cache``: share prompt-prefix
    pages across requests (None -> on whenever paged).
    ``prefill_chunk``: span size for chunked prefill (clamped to the
    window; the final partial chunk buckets to pow2).

    ``mesh``: a (data, model) ``jax.sharding.Mesh`` — when set, every
    prefill/decode/span program compiles under NamedSharding: KV-head
    pools shard over "model" (GQA replicating via the AxisRules
    divisibility fallback, reported once in ``dropped_rules``), batch
    slots over "data", host bookkeeping replicated. ``rules`` is an
    AxisRules or a RULE_SETS name.

    ``disaggregate``: prefill/decode disaggregation (paged only) —
    ``prefill_workers`` dedicated workers chunk-prefill cold prompts
    (placed by queue depth) and hand finished pages to the decode side
    over a modeled ``transfer_link`` ("ici" intra-pod | "dcn" cross-pod)
    of hardware generation ``transfer_hw``; arriving slots stay *parked*
    (frozen, token-identical on activation) until the modeled transfer
    lands, and the traffic/stall accounting shows up in
    ``transfer_stats()``."""

    cfg: ModelConfig
    ctx: ModelContext
    window: int
    max_batch: int = 4
    chunk: int = 8
    page_size: int = 8
    num_pages: Optional[int] = None
    paged: Optional[bool] = None  # None -> auto by family
    eos_id: Optional[int] = None
    temperature: float = 0.0
    draft_k: int = 0
    prefix_cache: Optional[bool] = None
    prefill_chunk: int = 128  # span size for chunked prefill
    mesh: Any = None  # serving mesh (None -> single host)
    rules: Any = "baseline_dp_tp"  # AxisRules or RULE_SETS name
    disaggregate: bool = False
    prefill_workers: int = 1
    transfer_link: str = "ici"  # "ici" | "dcn"
    transfer_hw: str = "tpu_v5e"  # hwspec generation for the transfer
    # resilience: a serve.faults.FaultInjector turns on the chaos
    # harness (page CRC stamping, per-boundary injection + detection +
    # replay); None leaves the fault-free path bit-identical to an
    # engine without the harness. ``admission`` is a
    # serve.admission.AdmissionController (None admits everything).
    # ``retry_budget`` bounds fault replays per request before the
    # deterministic terminal failure (state="failed").
    faults: Any = None
    admission: Any = None
    retry_budget: int = 3
    metrics: Any = None  # obs.MetricsRegistry (None -> fresh enabled one)
    tracer: Any = None  # obs.SpanTracer (None -> disabled)

    def __post_init__(self) -> None:
        cfg, ctx = self.cfg, self.ctx
        if self.paged is None:
            self.paged = api.supports_paged_decode(cfg)
        if self.paged and not api.supports_paged_decode(cfg):
            raise ValueError(f"{cfg.name}: paged serving unsupported")
        if self.draft_k and not self.paged:
            raise ValueError("speculative decoding (draft_k > 0) requires "
                             "the paged KV backend")
        if self.draft_k < 0:
            raise ValueError("draft_k must be >= 0")
        if self.prefix_cache is None:
            self.prefix_cache = self.paged
        if self.prefix_cache and not self.paged:
            raise ValueError("prefix caching requires the paged KV backend")
        if self.disaggregate and not self.paged:
            raise ValueError("prefill/decode disaggregation requires the "
                             "paged KV backend (pages are the handoff unit)")
        if self.prefill_workers < 1:
            raise ValueError("prefill_workers must be >= 1")
        if isinstance(self.rules, str):
            self.rules = RULE_SETS[self.rules]
        if not isinstance(self.rules, AxisRules):
            raise ValueError(f"rules must be AxisRules or one of "
                             f"{sorted(RULE_SETS)}")
        self.dropped_rules: List[str] = []
        self._dropped_raw: List[Tuple[str, int]] = []
        if self.mesh is not None:
            self.ctx = ctx = self._mesh_context(ctx)
        # Telemetry is host-side only (never touches a device program),
        # so an instrumented engine is token-identical to a bare one.
        # ``counters``/``disagg_stats`` keep their historical dict-style
        # call sites via CounterDict facades; the registry owns the
        # numbers under "serve_"-prefixed names (obs.CATALOG).
        if self.metrics is None:
            self.metrics = MetricsRegistry()
        if self.tracer is None:
            self.tracer = SpanTracer(enabled=False)
        self.steptrace = StepTrace(
            source="serve", meta={"arch": self.cfg.name})
        self.counters = CounterDict(
            self.metrics,
            ("prefills", "chunks", "decode_steps", "host_syncs",
             "pertoken_steps", "pages_trimmed", "suffix_prefills",
             "prompt_tokens", "cached_prompt_tokens", "spec_steps",
             "spec_tokens", "prefill_span_calls", "span_prefill_compiles",
             "span_prefill_dense_compiles"),
            prefix="serve_")
        m = self.metrics
        self._m = {
            "ttft": m.histogram("serve_ttft_s"),
            "tpot": m.histogram("serve_tpot_s"),
            "e2e": m.histogram("serve_e2e_s"),
            "queue_wait": m.histogram("serve_queue_wait_steps",
                                      edges=QUEUE_WAIT_BUCKETS_STEPS),
            "prefill_hist": m.histogram("serve_prefill_s"),
            "chunk_hist": m.histogram("serve_chunk_s"),
            "prefill_time": m.counter("serve_prefill_time_s"),
            "decode_time": m.counter("serve_decode_time_s"),
            "prefill_tokens": m.counter("serve_prefill_tokens"),
            "decode_tokens": m.counter("serve_decode_tokens"),
            "generated_tokens": m.counter("serve_generated_tokens"),
            "admitted": m.counter("serve_requests_admitted"),
            "finished": m.counter("serve_requests_finished"),
            "preempted": m.counter("serve_requests_preempted"),
        }
        self._trace_pid = self.tracer.process("serve")
        # decode chunks run all slots at once: one "device" lane past
        # the per-slot request lanes (tids 0..max_batch-1)
        self._device_tid = self.tracer.thread(
            self._trace_pid, self.max_batch, "device")
        self._req_obs: Dict[int, Dict[str, float]] = {}
        self._park_spans: set = set()
        if self.paged:
            # +1 page of table headroom: a finished slot's frozen pos can
            # sit exactly at `window`, whose page index must still resolve
            # (to the trash page) instead of clamping into a live page.
            # Speculative spans write up to draft_k positions past the
            # frontier; those slots must resolve (to trash) too.
            self.pages_per_seq = (
                -(-(self.window + self.draft_k) // self.page_size) + 1)
            if self.num_pages is None:
                self.num_pages = 1 + self.max_batch * self.pages_per_seq
            self.kv: Any = PagedKVCache(
                cfg, ctx, self.num_pages, self.page_size, self.max_batch,
                self.pages_per_seq, mesh=self.mesh, rules=self.rules,
                dropped=self._dropped_raw)
        else:
            self.kv = DenseKVCache(cfg, ctx, self.window, self.max_batch,
                                   mesh=self.mesh, rules=self.rules,
                                   dropped=self._dropped_raw)
        self._note_dropped()
        # Pure state-family stacks (mamba/rwkv) carry O(1) state, so the
        # dense prefill would otherwise compile once per prompt length.
        # Front-padding to power-of-two buckets (masked embeddings; the
        # recurrent state stays zero through the pad prefix) bounds the
        # compile count to log2(window).
        self.bucket_prefill = (not self.paged
                               and not cfg.is_encoder_decoder
                               and set(cfg.sublayer_kinds()) <=
                               {"mamba", "rwkv"})
        # Chunked prefill through the dense span path for any remaining
        # decoder-only stack with attention sublayers (hybrid jamba, or a
        # pure-attention stack forced onto the dense backend): prompts
        # are right-aligned into fixed-size spans at absolute positions,
        # so attention needs no front padding and every prompt length
        # reuses ONE compiled program. Requires append-only (non-ring)
        # caches, so SWA archs whose window exceeds the serve window are
        # excluded. mrope positions thread through the span paths (sliced
        # per chunk from the request's extras).
        self.chunk_prefill = (not self.paged
                              and not self.bucket_prefill
                              and not cfg.is_encoder_decoder
                              and (cfg.sliding_window is None
                                   or self.window <= cfg.sliding_window))
        # span size for chunked prefill (paged cold + suffix, dense)
        self.span_len = max(1, min(self.prefill_chunk, self.window))
        self.prefill_bucket_sizes: set = set()
        self._use_spec = False  # per-run: draft_k > 0 and greedy temp
        # disaggregation state: parked slots (admitted but frozen while
        # their modeled page transfer is in flight) and traffic counters
        self._parked: Dict[int, int] = {}
        self.transfer_model: Optional[PageTransferModel] = None
        if self.disaggregate:
            self.page_bytes = self.kv.per_token_bytes() * self.page_size
            self.transfer_model = PageTransferModel(
                page_bytes=self.page_bytes, chunk=self.chunk,
                resident_bytes=self.max_batch * self.window
                * self.kv.per_token_bytes(),
                hw=self.transfer_hw, link=self.transfer_link)
        self.disagg_stats = CounterDict(
            self.metrics,
            ("transfers", "transfer_pages", "transfer_bytes",
             "transfer_stall_boundaries", "decode_idle_boundaries",
             "boundaries", "prefill_depth_sum", "prefill_depth_peak",
             "decode_depth_sum", "decode_depth_peak"),
            prefix="serve_")
        if self.retry_budget < 0:
            raise ValueError("retry_budget must be >= 0")
        self.fault_stats = CounterDict(
            self.metrics,
            ("fault_worker_failures", "fault_page_corruptions",
             "fault_pages_quarantined", "fault_transfer_drops",
             "fault_stragglers", "fault_detections", "retry_requeues",
             "retry_failures", "shed_requests", "shed_spec_chunks"),
            prefix="serve_")
        if self.faults is not None and self.paged:
            # CRC-stamp published pages so injected corruption is caught
            # at the next boundary — before any chunk could read it
            self.kv.integrity_checks = True
        self._build_jitted()
        self._reset_carry()

    # ----------------------------------------------------------- mesh wiring

    def _mesh_context(self, ctx: ModelContext) -> ModelContext:
        """Rebuild the model context with the serving mesh threaded in:
        ``shard`` becomes a logical_constraint against (mesh, rules) so
        every activation/page annotation resolves under GSPMD, and the
        mesh/axis names ride along for the shard_map'd paged kernels."""
        mesh, rules = self.mesh, self.rules

        def shard(x: Array, logical: Tuple[Optional[str], ...]) -> Array:
            return logical_constraint(x, logical, mesh, rules)

        return ModelContext(
            compute_dtype=ctx.compute_dtype, q_chunk=ctx.q_chunk,
            shard=shard, mamba_chunk=ctx.mamba_chunk,
            rwkv_chunk=ctx.rwkv_chunk, attn_impl=ctx.attn_impl,
            decode_cache_dtype=ctx.decode_cache_dtype,
            full_cache_window=ctx.full_cache_window, mesh=mesh,
            data_axis="data", model_axis="model",
            moe_dispatch=ctx.moe_dispatch, moe_impl=ctx.moe_impl)

    def _note_dropped(self, raw=None) -> None:
        """Fold freshly-recorded divisibility fallbacks into the one-time
        report: visible in logs at WARNING and in ``sharding_report``."""
        if self.mesh is None:
            return
        if raw is not None:
            self._dropped_raw.extend(raw)
        lines = summarize_dropped(self._dropped_raw, self.mesh, self.rules)
        new = [ln for ln in lines if ln not in self.dropped_rules]
        if new:
            self.dropped_rules.extend(new)
            log.warning("serve sharding fallbacks (%s on %s): %s",
                        self.rules.name, self.cfg.name, "; ".join(new))

    @property
    def sharding_report(self) -> Dict[str, Any]:
        """Mesh layout + every dropped-rule fallback seen so far."""
        if self.mesh is None:
            return {"mesh": None, "rules": self.rules.name,
                    "dropped_rules": []}
        return {"mesh": dict(zip(self.mesh.axis_names,
                                 self.mesh.devices.shape)),
                "rules": self.rules.name,
                "dropped_rules": list(self.dropped_rules)}

    def shard_params(self, params: PyTree) -> PyTree:
        """device_put the parameter tree onto the serving mesh per the
        logical rules (identity without a mesh). ``run()`` applies this
        automatically; calling it once up front skips the first-boundary
        transfer. Already-placed trees are a no-op device_put."""
        if self.mesh is None:
            return params
        logical = axes_tree(api.model_specs(self.cfg))
        shapes = jax.tree.map(lambda p: p.shape, params)
        raw: List[Tuple[str, int]] = []
        shardings = tree_shardings(logical, shapes, self.mesh, self.rules,
                                   raw)
        out = jax.device_put(params, shardings)
        self._note_dropped(raw)
        return out

    def transfer_stats(self) -> Dict[str, float]:
        """Disaggregation traffic/stall/queue-depth accounting (empty
        dict when ``disaggregate`` is off)."""
        if not self.disaggregate:
            return {}
        st = dict(self.disagg_stats)
        n = max(1, st.pop("boundaries"))
        st["prefill_depth_mean"] = st.pop("prefill_depth_sum") / n
        st["decode_depth_mean"] = st.pop("decode_depth_sum") / n
        st["transfer_s_per_page"] = self.transfer_model.transfer_s(1)
        st["link"] = self.transfer_link
        return st

    # ------------------------------------------------------------ jit build

    @staticmethod
    def _pick(logits: Array, key: Array, temp: Array) -> Array:
        """logits (B,1,V) -> (B,1) int32 next tokens.

        ``temp`` is a traced scalar: greedy (temp <= 0) and sampled paths
        share one compilation, so changing the temperature neither
        recompiles nor requires rebuilding the engine."""
        last = logits[:, -1, :].astype(jnp.float32)
        greedy = jnp.argmax(last, axis=-1)
        sampled = jax.random.categorical(
            key, last / jnp.maximum(temp, 1e-6), axis=-1)
        return jnp.where(temp > 0.0, sampled,
                         greedy)[:, None].astype(jnp.int32)

    @staticmethod
    def _prefill_key(key: Array, rid: int) -> Array:
        """Per-request sampling key for the first token. Double fold (a
        dedicated stream id, then the rid) keeps it disjoint from the
        single-fold per-step chunk keys and from other admissions in the
        same boundary."""
        return jax.random.fold_in(jax.random.fold_in(key, 0x9e3779), rid)

    def _draft_tokens(self, hist: Array, pos: Array, tok: Array) -> Array:
        """n-gram prompt-lookup drafter, fully on device.

        hist: (B, window) token at each absolute position (< pos valid);
        tok: (B, 1) the current input token (position ``pos``, not yet in
        hist). Finds the latest earlier occurrence of the tip bigram
        (hist[pos-1], tok) and proposes the ``draft_k`` tokens that
        followed it. Misses return -1 (never matches a greedy target, so
        verification rejects the whole draft). Drafts are *advisory
        only*: acceptance compares against the model's own greedy
        targets, so a bad draft can cost speed, never correctness."""
        b, w = hist.shape
        dk = self.draft_k
        bidx = jnp.arange(b)
        idx = jnp.arange(w)[None, :]
        prev = jnp.pad(hist, ((0, 0), (1, 0)))[:, :w]  # hist shifted right
        last = hist[bidx, jnp.clip(pos - 1, 0, w - 1)]  # (B,)
        m = (hist == tok) & (prev == last[:, None])
        m &= (idx >= 1) & (idx < pos[:, None])
        # prefer the latest match whose dk-token continuation is fully
        # inside known history (j + dk <= pos - 1); matches closer to the
        # tip would propose positions that are not written yet
        j_full = jnp.where(m & (idx + dk <= pos[:, None] - 1),
                           idx, -1).max(axis=1)
        j_part = jnp.where(m & (idx <= pos[:, None] - 2),
                           idx, -1).max(axis=1)
        j = jnp.where(j_full >= 0, j_full, j_part)
        gidx = jnp.clip(j[:, None] + 1 + jnp.arange(dk)[None, :], 0, w - 1)
        drafts = hist[bidx[:, None], gidx]
        # tokens proposed past the known tip are unknown: void them
        known = j[:, None] + 1 + jnp.arange(dk)[None, :] < pos[:, None]
        drafts = jnp.where(known & (j[:, None] >= 0), drafts, -1)
        return drafts.astype(jnp.int32)

    def _build_jitted(self) -> None:
        cfg, ctx = self.cfg, self.ctx
        eos = self.eos_id

        # ---- prefill ----------------------------------------------------
        def prefill_dense(params, batch, key, temp):
            logits, cache = api.prefill_fn(params, batch, cfg, ctx,
                                           window=self.window)
            first = self._pick(logits, key, temp)
            return first, cache

        def prefill_bucketed(params, tokens, pad_left, key, temp):
            logits, cache = api.prefill_fn(
                params, {"tokens": tokens}, cfg, ctx, window=self.window,
                pad_left=pad_left)
            first = self._pick(logits, key, temp)
            return first, cache

        self._prefill_dense = jax.jit(prefill_dense)
        self._prefill_bucketed = jax.jit(prefill_bucketed)

        # ---- span prefill (paged): cold chunks AND cached suffixes ------
        # Every paged prefill rides the span-decode datapath in fixed-size
        # chunks: queries attend to everything already in the pages (a
        # cold chunk's predecessors, or an adopted cached prefix) through
        # the page table, and the chunk's k/v scatter straight into the
        # slot's pages — quantized on write for int8 pools, streamed back
        # by the same kernels decode uses. One compiled program serves
        # every prompt length (the trace-time counter below is the
        # compile-count regression probe).
        def prefill_span(params, pages, span, table, pos0, valid, key,
                         temp, mrope=None):
            # trace-time: jax runs this Python once per compiled program
            # variant, so compile_event counts compilations (a cache hit
            # never re-enters the tracer; a retrace legitimately counts)
            self.metrics.compile_event("serve_span_prefill")
            state = {"pages": pages, "page_table": table, "pos": pos0}
            # only the chunk's last real token needs logits: the gather
            # happens before the lm head, so the vocab projection is
            # (1, 1, V) per chunk, not (1, span, V)
            idx = jnp.clip(valid - 1, 0, span.shape[1] - 1)
            logits, new_state = api.decode_span_paged_fn(
                params, span, state, cfg, ctx, valid=valid, logits_at=idx,
                mrope_positions=mrope)
            first = self._pick(logits, key, temp)
            return first, new_state["pages"]

        self._prefill_span = jax.jit(prefill_span, donate_argnums=(1,))

        # ---- span prefill (dense): chunked prefill for hybrid stacks ----
        # Right-aligned chunks at absolute positions: only the FIRST chunk
        # carries (dead) front padding, flagged by pos < 0 inside
        # lm_decode_span — attention writes drop, recurrent state threads
        # through chunks untouched by the pad.
        def prefill_span_dense(params, cache, span, pos0, key, temp,
                               mrope=None):
            # trace-time compile counter; see prefill_span above
            self.metrics.compile_event("serve_span_prefill_dense")
            state = dict(cache)
            state["pos"] = pos0
            # right-aligned chunks end on a live token: its logits alone
            # are gathered before the lm head (see prefill_span)
            last = jnp.full((span.shape[0],), span.shape[1] - 1, jnp.int32)
            logits, new_state = api.decode_span_fn(
                params, span, state, cfg, ctx, logits_at=last,
                mrope_positions=mrope)
            first = self._pick(logits, key, temp)
            return first, {"blocks": new_state["blocks"]}

        self._prefill_span_dense = jax.jit(prefill_span_dense,
                                           donate_argnums=(1,))

        # ---- copy-on-write page copy (prefix cache fork) ----------------
        def copy_page(pages, src, dst):
            new = {}
            for sl, sub in pages.items():
                new[sl] = {name: arr.at[:, dst].set(arr[:, src])
                           for name, arr in sub.items()}
            return new

        self._copy_page = jax.jit(copy_page, donate_argnums=(0,))

        # ---- dense slot write -------------------------------------------
        def write_dense(cache, row_cache, slot):
            blocks = jax.tree.map(lambda c, r: c.at[:, slot].set(r[:, 0]),
                                  cache["blocks"], row_cache["blocks"])
            out = dict(cache)
            out["blocks"] = blocks
            return out

        self._write_dense = jax.jit(write_dense, donate_argnums=(0,))

        # ---- device-resident decode chunk -------------------------------
        def chunk_body(params, table, temp, carry, i):
            tok, pos, done, n_out, max_new, key, cache = carry
            if self.paged:
                state = {"pages": cache, "page_table": table, "pos": pos}
                logits, new_state = api.decode_paged_fn(
                    params, tok, state, cfg, ctx)
                new_cache = new_state["pages"]
            else:
                state = dict(cache)
                state["pos"] = pos
                logits, new_state = api.decode_fn(
                    params, tok, state, cfg, ctx)
                new_cache = {k: v for k, v in new_state.items()
                             if k != "pos"}
            emitted = jnp.where(done, PAD_TOKEN, tok[:, 0])
            n_out = n_out + jnp.where(done, 0, 1)
            newly_done = ~done & (n_out >= max_new)
            if eos is not None:
                newly_done |= ~done & (tok[:, 0] == eos)
            done = done | newly_done
            # finished slots freeze: their (garbage) writes keep landing on
            # the same slot/trash page and their position stops advancing
            pos = jnp.where(done, pos, pos + 1)
            nxt = self._pick(logits, jax.random.fold_in(key, i), temp)
            tok = jnp.where(done[:, None], tok, nxt)
            return (tok, pos, done, n_out, max_new, key, new_cache), emitted

        def run_chunk(params, table, tok, pos, done, n_out, max_new, key,
                      temp, t0, cache):
            def step(carry, i):
                return chunk_body(params, table, temp, carry, i)

            carry0 = (tok, pos, done, n_out, max_new, key, cache)
            carry, toks = jax.lax.scan(
                step, carry0, t0 + jnp.arange(self.chunk))
            tok, pos, done, n_out, max_new, _, cache = carry
            return tok, pos, done, n_out, cache, toks.T  # toks (B, C)

        self._run_chunk = jax.jit(run_chunk, donate_argnums=(10,))

        # ---- speculative decode chunk (draft_k > 0) ---------------------
        # One scan step = draft -> one span decode scoring (1 + draft_k)
        # tokens -> accept the longest prefix matching the model's own
        # greedy targets -> emit 1..1+draft_k tokens. Rollback of the
        # rejected tail is the position bookkeeping alone: its k/v stay
        # in append-only pages beyond the validity frontier and are
        # rewritten before the frontier reaches them.
        dk = self.draft_k

        def spec_chunk_body(params, table, temp, carry, i):
            tok, pos, done, n_out, max_new, key, cache, hist = carry
            b = tok.shape[0]
            bidx = jnp.arange(b)
            w = hist.shape[1]
            drafts = self._draft_tokens(hist, pos, tok)  # (B, dk)
            span = jnp.concatenate([tok, drafts], axis=1)  # (B, 1+dk)
            state = {"pages": cache, "page_table": table, "pos": pos}
            logits, new_state = api.decode_span_paged_fn(
                params, span, state, cfg, ctx)
            new_cache = new_state["pages"]
            greedy = jnp.argmax(
                logits.astype(jnp.float32), axis=-1).astype(jnp.int32)
            # greedy[:, t] is the model's target for position pos + t + 1;
            # under sampling (temp > 0) greedy-match acceptance would
            # change the output distribution, so drafts are voided (the
            # guard is belt-and-braces: run() routes temp > 0 to the
            # plain chunk and never pays for the span at all).
            match = (drafts == greedy[:, :dk]) & (temp <= 0.0)
            accepted = jnp.cumprod(match.astype(jnp.int32), axis=1)
            a = accepted.sum(axis=1)  # (B,) accepted draft count
            t_idx = jnp.arange(1 + dk)
            emit_ok = (t_idx[None, :] <= a[:, None]) & ~done[:, None]
            emit_ok &= (n_out[:, None] + t_idx[None, :]) < max_new[:, None]
            if eos is not None:
                is_eos = span == eos
                prior = jnp.cumsum(is_eos.astype(jnp.int32),
                                   axis=1) - is_eos
                emit_ok &= prior == 0  # nothing emits past an EOS
            emitted = jnp.where(emit_ok, span, PAD_TOKEN)
            n_emit = emit_ok.sum(axis=1).astype(jnp.int32)
            n_out = n_out + n_emit
            newly_done = ~done & (n_out >= max_new)
            if eos is not None:
                newly_done |= ~done & jnp.any(emit_ok & is_eos, axis=1)
            done = done | newly_done
            # token history: scatter the emitted span at positions pos+t
            wpos = jnp.clip(pos[:, None] + t_idx[None, :], 0, w - 1)
            cur = hist[bidx[:, None], wpos]
            hist = hist.at[bidx[:, None], wpos].set(
                jnp.where(emit_ok, span, cur))
            pos = pos + n_emit  # rollback == not advancing past acceptance
            pick0 = self._pick(logits[:, :1], jax.random.fold_in(key, i),
                               temp)
            bonus = greedy[bidx, jnp.clip(a, 0, dk)][:, None]
            nxt = jnp.where(temp > 0.0, pick0, bonus)
            tok = jnp.where(done[:, None], tok, nxt)
            return ((tok, pos, done, n_out, max_new, key, new_cache, hist),
                    emitted)

        def run_chunk_spec(params, table, tok, pos, done, n_out, max_new,
                           key, temp, t0, cache, hist):
            def step(carry, i):
                return spec_chunk_body(params, table, temp, carry, i)

            carry0 = (tok, pos, done, n_out, max_new, key, cache, hist)
            carry, toks = jax.lax.scan(
                step, carry0, t0 + jnp.arange(self.chunk))
            tok, pos, done, n_out, max_new, _, cache, hist = carry
            # toks (C, B, 1+dk) -> (B, C, 1+dk), chronological per slot
            return (tok, pos, done, n_out, cache, hist,
                    toks.transpose(1, 0, 2))

        if dk:
            self._run_chunk_spec = jax.jit(run_chunk_spec,
                                           donate_argnums=(10, 11))

    # --------------------------------------------------------- carry state

    def _reset_carry(self) -> None:
        b = self.max_batch
        self._tok = jnp.zeros((b, 1), jnp.int32)
        self._pos = jnp.zeros((b,), jnp.int32)
        self._done = jnp.ones((b,), bool)  # empty slots are "done"
        self._n_out = jnp.zeros((b,), jnp.int32)
        self._max_new = jnp.ones((b,), jnp.int32)
        self._t = 0  # global decode-step clock (also the sampling stream)
        if self.draft_k:
            # token-at-position history for the prompt-lookup drafter.
            # draft_k + 1 columns of headroom keep every span scatter
            # index in range and distinct (a clipped duplicate write
            # would resolve nondeterministically).
            self._hist = jnp.zeros(
                (b, self.window + self.draft_k + 1), jnp.int32)

    @staticmethod
    def _pow2_bucket(t: int, cap: int) -> int:
        """Compile length for a partial span: pow2 >= t (floor 4), capped
        at the full span size — the program *family* is O(log span_len),
        constant in prompt length, and a short suffix never pays a
        full-span query block."""
        return min(cap, max(4, 1 << (t - 1).bit_length()))

    def _span_prefill_paged(self, params, slot: int, tokens: np.ndarray,
                            start: int, key: Array, temp: Array,
                            mrope: Optional[np.ndarray] = None) -> Array:
        """Prefill ``tokens`` at absolute positions ``start..`` through
        the span-decode datapath in fixed-size chunks — cold prompts
        (start=0) and cached-prefix suffixes (start=cached) share the
        same compiled program family (full-span program + pow2 buckets
        for the final partial chunk). Back padding inside a partial
        chunk writes to the trash page; logits index the final real
        token. ``mrope`` (3, S_total) carries the request's explicit
        multimodal rope rows indexed by *absolute* token position; each
        chunk slices its window (pad slots are dead: zero rows)."""
        s_len = self.span_len
        if not self.kv.ensure_private(slot, start, self._copy_page):
            raise RuntimeError("page pool exhausted during CoW fork")
        first = None
        i = 0
        while i < len(tokens):
            t = min(s_len, len(tokens) - i)
            b_len = self._pow2_bucket(t, s_len)
            span = np.zeros((1, b_len), np.int32)
            span[0, :t] = tokens[i:i + t]
            chunk_m = None
            if mrope is not None:
                cm = np.zeros((3, 1, b_len), np.int32)
                cm[:, 0, :t] = mrope[:, start + i:start + i + t]
                chunk_m = jnp.asarray(cm)
            first, self.kv.pages = self._prefill_span(
                params, self.kv.pages, jnp.asarray(span),
                self.kv.table_row(slot),
                jnp.full((1,), start + i, jnp.int32),
                jnp.full((1,), t, jnp.int32), key, temp, chunk_m)
            self.counters["prefill_span_calls"] += 1
            i += t
        return first

    def _span_prefill_dense(self, params, slot: int, tokens: np.ndarray,
                            key: Array, temp: Array,
                            mrope: Optional[np.ndarray] = None) -> Array:
        """Chunked prefill on the dense backend (hybrid stacks): the
        prompt is RIGHT-aligned into fixed-size spans so only the first
        chunk is (front-)padded — dead positions sit at negative absolute
        positions, attention stays absolute-positioned, and recurrent
        state threads through the chunks. The first (partial) chunk
        buckets to pow2; every other chunk reuses the full-span program.
        ``mrope`` (3, S) explicit rope rows; the dead front pad gets zero
        rows (its writes are dropped anyway)."""
        s_len = self.span_len
        s = len(tokens)
        r = s % s_len or min(s, s_len)  # first (partial) chunk tokens
        b0 = self._pow2_bucket(r, s_len)
        pad = b0 - r
        padded = np.zeros((1, pad + s), np.int32)
        padded[0, pad:] = tokens
        m_full = None
        if mrope is not None:
            m_full = np.zeros((3, pad + s), np.int32)
            m_full[:, pad:] = mrope[:, :s]
        cache = {"blocks": jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            api.cache_spec(self.cfg, 1, self.window, self.ctx)["blocks"])}
        first = None
        i = 0
        while i < padded.shape[1]:
            b_len = b0 if i == 0 else s_len
            chunk_m = (None if m_full is None else
                       jnp.asarray(m_full[:, None, i:i + b_len]))
            first, cache = self._prefill_span_dense(
                params, cache, jnp.asarray(padded[:, i:i + b_len]),
                jnp.full((1,), i - pad, jnp.int32), key, temp, chunk_m)
            self.counters["prefill_span_calls"] += 1
            i += b_len
        self.kv.write_prefill(self._write_dense, slot, cache)
        return first

    def _req_mrope(self, req: Request, s: int) -> Optional[np.ndarray]:
        """(3, S) absolute-indexed mrope rows for a resume prompt of
        length ``s``: the request's explicit positions, extended past the
        original prompt (generated tokens folded in on resume) by the
        standard max(pos)+1 text continuation."""
        if self.cfg.pos_emb != "mrope":
            return None
        v = req.extras.get("positions")
        if v is None:
            return None  # text default: span paths broadcast positions
        m = np.asarray(v, np.int32).reshape(3, -1)
        if m.shape[1] < s:
            tail = int(m.max()) + 1 + np.arange(s - m.shape[1],
                                                dtype=np.int32)
            m = np.concatenate(
                [m, np.broadcast_to(tail, (3, tail.size))], axis=1)
        return m[:, :s]

    def _admit_into_slot(self, params, req: Request, slot: int,
                         key: Array, temp: Array) -> None:
        rp = req.resume_prompt()
        s = len(rp)
        self.counters["prefills"] += 1
        pkey = self._prefill_key(key, req.rid)
        cached = req.cached_prefix_len if self.paged else 0
        mrope = self._req_mrope(req, s)
        if self.paged:
            # every paged prefill is a chunked span prefill; a prefix hit
            # just starts past the adopted pages (suffix-only compute)
            first = self._span_prefill_paged(params, slot, rp[cached:],
                                             cached, pkey, temp, mrope)
            if cached > 0:
                self.counters["suffix_prefills"] += 1
        elif self.chunk_prefill and not (req.extras.keys() - {"positions"}):
            first = self._span_prefill_dense(params, slot, rp, pkey, temp,
                                             mrope)
        elif self.bucket_prefill and not req.extras:
            sb = 1 << max(3, (s - 1).bit_length())  # pow2 >= s, floor 8
            self.prefill_bucket_sizes.add(sb)
            padded = np.zeros((1, sb), np.int32)
            padded[0, sb - s:] = rp
            first, cache = self._prefill_bucketed(
                params, jnp.asarray(padded),
                jnp.full((1,), sb - s, jnp.int32), pkey, temp)
            self.kv.write_prefill(self._write_dense, slot, cache)
        else:
            batch = {"tokens": jnp.asarray(rp[None, :])}
            for k, v in req.extras.items():
                batch[k] = jnp.asarray(v)
            first, cache = self._prefill_dense(params, batch, pkey, temp)
            self.kv.write_prefill(self._write_dense, slot, cache)
        if self.paged and self.prefix_cache and mrope is None:
            # publish the full prompt pages so later admissions (and this
            # request's own resume after a preemption) can share them.
            # Explicit-mrope requests never publish (or adopt): the index
            # is content-addressed on tokens alone, and the same tokens
            # under different position rows hold different KV.
            self.kv.register_prefix(slot, rp)
        if self.draft_k and self._use_spec:
            row = np.zeros(self.window + self.draft_k + 1, np.int32)
            row[:s] = rp
            self._hist = self._hist.at[slot].set(jnp.asarray(row))
        self._tok = self._tok.at[slot].set(first[0])
        self._pos = self._pos.at[slot].set(s)
        self._done = self._done.at[slot].set(False)
        self._n_out = self._n_out.at[slot].set(len(req.generated))
        self._max_new = self._max_new.at[slot].set(req.max_new)

    # ------------------------------------------------------------- faults

    def _fail_slot(self, slot: int, sched: ContinuousBatchingScheduler,
                   clock: int, reason: str) -> None:
        """Fault recovery for one running slot: release its pages,
        freeze the slot, and replay the request (re-admission re-prefills
        ``resume_prompt()`` past surviving cached pages — token-identical
        under greedy, same as preemption resume) with exponential backoff
        until the retry budget forces the deterministic terminal
        failure."""
        req = sched.running.get(slot)
        if req is None:
            return
        if self.paged:
            self.kv.release(slot)
        self._done = self._done.at[slot].set(True)
        self._parked.pop(slot, None)
        pid = self._trace_pid
        if slot in self._park_spans:
            self.tracer.end(pid=pid, tid=slot)
            self._park_spans.discard(slot)
        self.tracer.end(pid=pid, tid=slot)  # req span
        self.tracer.instant("fault_replay", pid=pid, tid=slot,
                            cat="serve",
                            args={"rid": req.rid, "reason": reason,
                                  "retries": req.retries})
        if req.retries >= self.retry_budget:
            sched.fail(req)
            self.fault_stats["retry_failures"] += 1
        else:
            backoff = self.chunk * (1 << min(req.retries, 6))
            sched.requeue(req, not_before=clock + backoff)
            self.fault_stats["retry_requeues"] += 1

    def _apply_faults(self, boundary: int, clock: int,
                      sched: ContinuousBatchingScheduler,
                      pool: Optional[PrefillWorkerPool]) -> None:
        """Inject this boundary's scheduled faults, then run detection —
        in that order, before the chunk dispatch, so corrupted KV is
        quarantined before any decode step could read it (which is what
        makes survivor token-parity exact rather than probabilistic)."""
        inj = self.faults
        fs = self.fault_stats
        pid = self._trace_pid
        if pool is not None:
            w = inj.worker_failure(boundary)
            if w is not None:
                lost = pool.fail_worker(w % pool.n_workers, clock)
                fs["fault_worker_failures"] += 1
                fs["fault_detections"] += 1
                self.tracer.instant(
                    "worker_fail", pid=pid, tid=self._device_tid,
                    cat="serve", args={"worker": w % pool.n_workers,
                                       "replaced": len(lost)})
        if pool is not None and self._parked:
            r = inj.transfer_drop(boundary)
            if r is not None:
                slot = sorted(self._parked)[r % len(self._parked)]
                retry = inj.plan.transfer_retry_boundaries
                self._parked[slot] = clock + retry * self.chunk
                fs["fault_transfer_drops"] += 1
                fs["fault_detections"] += 1
                self.tracer.instant(
                    "transfer_drop", pid=pid, tid=slot, cat="serve",
                    args={"retry_boundaries": retry})
        if self.paged:
            r = inj.page_flip(boundary)
            if r is not None:
                pids = self.kv.corruptible_pages()
                if pids:
                    self.kv.corrupt_page(pids[r % len(pids)])
                    fs["fault_page_corruptions"] += 1
            # detection: CRC-verify every stamped page; quarantine
            # mismatches and replay every request still mapping them
            for bad_pid, _h in self.kv.verify_integrity():
                fs["fault_detections"] += 1
                fs["fault_pages_quarantined"] += 1
                for slot in self.kv.slots_referencing(bad_pid):
                    self._fail_slot(slot, sched, clock,
                                    reason="kv_corruption")

    # ---------------------------------------------------------------- run

    def submit_check(self, req: Request) -> None:
        total = len(req.prompt) + req.max_new
        if total > self.window:
            raise ValueError(
                f"request {req.rid}: prompt+max_new={total} exceeds "
                f"window={self.window}")

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from cached pages."""
        total = self.counters["prompt_tokens"]
        return (self.counters["cached_prompt_tokens"] / total
                if total else 0.0)

    @property
    def acceptance_length(self) -> float:
        """Mean tokens emitted per speculative verify step (>= 1)."""
        steps = self.counters["spec_steps"]
        return (self.counters["spec_tokens"] / steps if steps else 1.0)

    def slo_summary(self) -> Dict[str, float]:
        """Serving SLO summary straight from the registry: TTFT/TPOT
        percentiles, queue wait, and the prefill/decode role split
        (time and tokens/s). All zeros on a disabled registry."""
        m = self._m
        pf_t = float(m["prefill_time"].value)
        dc_t = float(m["decode_time"].value)
        return {
            "requests": float(m["finished"].value),
            "ttft_p50_s": m["ttft"].quantile(0.5),
            "ttft_p95_s": m["ttft"].quantile(0.95),
            "tpot_p50_s": m["tpot"].quantile(0.5),
            "tpot_p95_s": m["tpot"].quantile(0.95),
            "queue_wait_p50_steps": m["queue_wait"].quantile(0.5),
            "prefill_time_s": pf_t,
            "decode_time_s": dc_t,
            "prefill_tok_s": (float(m["prefill_tokens"].value) / pf_t
                              if pf_t > 0 else 0.0),
            "decode_tok_s": (float(m["decode_tokens"].value) / dc_t
                             if dc_t > 0 else 0.0),
        }

    def run(self, params, requests: Sequence[Request], *,
            key: Optional[Array] = None,
            temperature: Optional[float] = None) -> Dict[int, np.ndarray]:
        """Drain all requests; returns {rid: generated tokens}."""
        params = self.shard_params(params)  # no-op without a mesh
        sched = ContinuousBatchingScheduler(self.max_batch)
        self.scheduler = sched
        key = key if key is not None else jax.random.key(0)
        temp = jnp.float32(self.temperature if temperature is None
                           else temperature)
        for req in requests:
            self.submit_check(req)
            sched.add(req)
        # greedy-match acceptance is only sound (and only profitable) for
        # greedy decoding: sampled runs take the plain 1-token chunk, so
        # they never pay for the (1 + draft_k)-query span
        self._use_spec = bool(self.draft_k) and float(temp) <= 0.0
        self._reset_carry()
        # request-lifecycle observation: wall stamps (ready/admit/first
        # token) per rid, feeding TTFT/TPOT/e2e histograms and lifecycle
        # spans. Host-side only; the device programs never see any of it.
        now = self.tracer.clock
        mtr = self._m
        pid = self._trace_pid
        self._req_obs = {}
        self._park_spans = set()
        run_t0 = now()
        pool: Optional[PrefillWorkerPool] = None
        if self.disaggregate:
            pool = PrefillWorkerPool(self.prefill_workers, self.span_len,
                                     self.chunk)
            self.prefill_pool = pool
            self._parked = {}
        clock = 0
        boundary = -1  # chunk-boundary index: the fault-schedule clock
        # max tokens one decode step can emit
        per_step = 1 + self.draft_k if self._use_spec else 1
        while sched.has_work() or (pool is not None and pool.pending()):
            boundary += 1
            wall = now()
            for r in sched.waiting:
                # "ready": first boundary at which the request is live
                # (arrived); queue-wait and e2e anchor here
                if r.arrival <= clock:
                    self._req_obs.setdefault(r.rid, {}) \
                        .setdefault("ready", wall)
            if self.admission is not None:
                # enqueue-time load shedding: a request whose best-case
                # first token already misses its TTFT deadline is dropped
                # before it consumes a prefill worker or decode slot
                for r in list(sched.waiting):
                    if r.arrival <= clock and self.admission.should_shed(
                            r, clock, chunk=self.chunk,
                            span_len=self.span_len,
                            disaggregated=pool is not None):
                        sched.shed_request(r)
                        self.fault_stats["shed_requests"] += 1
                        self.tracer.instant(
                            "shed", pid=pid, tid=self._device_tid,
                            cat="serve", args={"rid": r.rid})
            if pool is not None:
                # 0) disaggregation bookkeeping: activate parked slots
                #    whose modeled page transfer has landed (rewriting the
                #    frozen position's k/v is idempotent — see chunk_body's
                #    freeze contract — so activation is token-identical to
                #    co-located admission); route cold arrivals to the
                #    shallowest prefill worker queue; surface finished
                #    prefills back into the decode-side admission queue.
                for slot, ready in list(self._parked.items()):
                    if clock >= ready:
                        del self._parked[slot]
                        self._done = self._done.at[slot].set(False)
                        if slot in self._park_spans:
                            self.tracer.end(pid=pid, tid=slot)
                            self._park_spans.discard(slot)
                for r in [r for r in sched.waiting
                          if r.arrival <= clock and not r.prefill_done
                          and r.not_before <= clock]:
                    sched.waiting.remove(r)
                    pool.place(r, clock)
                for r in pool.pop_ready(clock):
                    sched.add(r)
                st = self.disagg_stats
                st["boundaries"] += 1
                depth = sum(pool.depths())
                st["prefill_depth_sum"] += depth
                st["prefill_depth_peak"] = max(st["prefill_depth_peak"],
                                               depth)
                st["decode_depth_sum"] += len(sched.waiting)
                st["decode_depth_peak"] = max(st["decode_depth_peak"],
                                              len(sched.waiting))
            if self.faults is not None:
                # inject this boundary's scheduled faults, then detect:
                # quarantined pages and failed slots are settled before
                # admission or the chunk can observe them
                self._apply_faults(boundary, clock, sched, pool)
            # 1) page headroom for running slots; preempt youngest on
            #    pressure (its pages free up for the older requests)
            if self.paged:
                # grow oldest-first so preemption (youngest-first) never
                # starves the requests with the most progress
                order = sorted(
                    sched.running,
                    key=lambda s: (sched.running[s].arrival,
                                   sched.running[s].rid))
                for slot in order:
                    if slot not in sched.running:
                        continue  # already preempted this boundary
                    req = sched.running[slot]
                    # tokens cached after the next chunk: prompt +
                    # emitted so far + chunk new writes (+1 boundary)
                    target = int(len(req.prompt) + len(req.generated)
                                 + self.chunk * per_step + 1)
                    while not self.kv.grow(slot, min(target, self.window)):
                        victim = sched.preempt_victim()
                        if victim is None:
                            raise RuntimeError(
                                "page pool too small for a single request")
                        vslot = victim.slot
                        sched.preempt(victim)
                        self.kv.release(vslot)
                        self._done = self._done.at[vslot].set(True)
                        # a parked victim's in-flight transfer is moot:
                        # its pages are gone; it re-prefills on resume
                        self._parked.pop(vslot, None)
                        if vslot in self._park_spans:
                            self.tracer.end(pid=pid, tid=vslot)
                            self._park_spans.discard(vslot)
                        self.tracer.end(pid=pid, tid=vslot)  # req span
                        self.tracer.instant(
                            "preempt", pid=pid, tid=vslot, cat="serve",
                            args={"rid": victim.rid})
                        mtr["preempted"].inc()
                        if vslot == slot:
                            break  # we were the youngest: self-preempted
            # 2) admissions into free slots (never preempt to admit)
            while True:
                req = sched.next_admittable(clock)
                slots = sched.free_slots()
                if req is None or not slots:
                    break
                slot = slots[0]
                if self.paged:
                    rp = req.resume_prompt()
                    # explicit-mrope requests bypass the content-addressed
                    # prefix index (same tokens, different position rows
                    # => different KV)
                    use_pc = (self.prefix_cache
                              and "positions" not in req.extras)
                    cached, pids = ((0, []) if not use_pc
                                    else self.kv.lookup_prefix(rp))
                    if cached:
                        self.kv.adopt_prefix(slot, pids)
                    need = len(rp) + self.chunk * per_step + 1
                    if not self.kv.grow(slot, min(need, self.window)):
                        if use_pc:
                            # undo adoption AND its counter bumps: the
                            # retry next boundary repeats the lookup
                            self.kv.abort_adoption(slot, cached, pids)
                        break  # no pages: wait for completions
                    req.cached_prefix_len = cached
                    self.counters["prompt_tokens"] += len(rp)
                    self.counters["cached_prompt_tokens"] += cached
                wall = now()
                o = self._req_obs.setdefault(req.rid, {})
                o.setdefault("ready", wall)
                resumed = "admit" in o  # re-admission after a preemption
                o["admit"] = wall
                mtr["admitted"].inc()
                mtr["queue_wait"].observe(float(clock - req.arrival))
                self.tracer.begin(
                    f"req:{req.rid}", pid=pid, tid=slot, cat="serve",
                    args={"rid": req.rid, "prompt": len(req.prompt),
                          "resumed": resumed})
                sched.admit(req, slot)
                self._admit_into_slot(params, req, slot, key, temp)
                dt = now() - wall
                n_prefill = (len(req.prompt) + len(req.generated)
                             - req.cached_prefix_len)
                mtr["prefill_hist"].observe(dt)
                mtr["prefill_time"].add(dt)
                mtr["prefill_tokens"].add(n_prefill)
                self.tracer.complete(
                    "prefill", dt, pid=pid, tid=slot, cat="serve",
                    args={"tokens": n_prefill,
                          "cached": req.cached_prefix_len})
                self.steptrace.record(
                    "prefill", dt, tokens=n_prefill,
                    cached=req.cached_prefix_len, batch=1)
                if pool is not None:
                    # the prefill ran on the prefill role; its finished
                    # pages now cross the modeled link. Park the slot
                    # (frozen exactly like a finished one) until the
                    # transfer's boundary count elapses.
                    moved = (self.kv.pages_for(len(rp))
                             - cached // self.page_size)
                    delay = self.transfer_model.delay_boundaries(moved)
                    self._parked[slot] = clock + delay * self.chunk
                    self._done = self._done.at[slot].set(True)
                    st = self.disagg_stats
                    st["transfers"] += 1
                    st["transfer_pages"] += moved
                    st["transfer_bytes"] += moved * self.page_bytes
                    self.tracer.begin(
                        "park", pid=pid, tid=slot, cat="serve",
                        args={"pages": moved, "delay_boundaries": delay})
                    self._park_spans.add(slot)
            if not sched.running:
                if sched.next_admittable(clock) is not None:
                    raise RuntimeError(
                        "admission stalled with an empty batch: the page "
                        "pool cannot hold one request (shrink window or "
                        "grow num_pages)")
                if pool is not None and pool.pending():
                    clock += self.chunk  # prefill workers still cooking
                    continue
                if not sched.waiting:
                    # shedding emptied the queue this boundary; the
                    # loop condition settles whether work remains
                    continue
                # idle: jump the trace clock to the next arrival (or the
                # earliest replay-backoff expiry, for requeued requests)
                nxt = min(max(r.arrival, r.not_before)
                          for r in sched.waiting)
                clock = max(clock + self.chunk, nxt)
                continue
            if (pool is not None and sched.running
                    and all(s in self._parked for s in sched.running)):
                # every running slot is frozen in transfer: the decode
                # role is idle, so skip the device chunk entirely (frozen
                # slots emit nothing and their state is untouched — the
                # skip is token-identical) and just advance the clock.
                clock += self.chunk
                st = self.disagg_stats
                st["transfer_stall_boundaries"] += 1
                st["decode_idle_boundaries"] += 1
                continue
            # 3) one device-resident chunk
            sched.record_occupancy(len(sched.running))
            chunk_t0 = now()
            live = sum(1 for s in sched.running if s not in self._parked)
            cache = self.kv.pages if self.paged else \
                {k: v for k, v in self.kv.cache.items() if k != "pos"}
            table = self.kv.table_device() if self.paged else jnp.zeros(
                (self.max_batch, 1), jnp.int32)
            # graceful degradation under queue pressure: spend this
            # boundary's FLOPs on a plain chunk instead of the
            # (1 + draft_k)-query speculative span. Token-identical by
            # construction (acceptance only ever matches the model's own
            # greedy targets), so the policy is a pure latency trade.
            use_spec = self._use_spec
            if use_spec and self.admission is not None \
                    and self.admission.drop_speculation(
                        len(sched.waiting)):
                use_spec = False
                self.fault_stats["shed_spec_chunks"] += 1
            if use_spec:
                (self._tok, self._pos, self._done, self._n_out, new_cache,
                 self._hist, toks) = self._run_chunk_spec(
                    params, table, self._tok, self._pos, self._done,
                    self._n_out, self._max_new, key, temp,
                    jnp.int32(self._t), cache, self._hist)
            else:
                (self._tok, self._pos, self._done, self._n_out, new_cache,
                 toks) = self._run_chunk(
                    params, table, self._tok, self._pos, self._done,
                    self._n_out, self._max_new, key, temp,
                    jnp.int32(self._t), cache)
            if self.paged:
                self.kv.pages = new_cache
            else:
                new_cache = dict(new_cache)
                new_cache["pos"] = self._pos
                self.kv.update(new_cache)
            self._t += self.chunk
            clock += self.chunk
            if self.faults is not None:
                # straggler: the chunk did one chunk of work but took
                # extra boundaries of wall clock. Purely a clock event —
                # per-request tokens are batch-composition independent,
                # so stragglers shift TTFT/queue waits, never tokens.
                extra = self.faults.straggler(boundary)
                if extra:
                    clock += extra * self.chunk
                    self.fault_stats["fault_stragglers"] += 1
                    self.tracer.instant(
                        "straggler", pid=pid, tid=self._device_tid,
                        cat="serve", args={"extra_boundaries": extra})
            self.counters["chunks"] += 1
            self.counters["decode_steps"] += self.chunk
            if pool is not None and self._parked:
                st = self.disagg_stats
                st["transfer_stall_boundaries"] += 1
                if all(s in self._parked for s in sched.running):
                    st["decode_idle_boundaries"] += 1
            # 4) drain: the single host sync per chunk
            toks_h, done_h, pos_h = jax.device_get(
                (toks, self._done, self._pos))
            self.counters["host_syncs"] += 1
            wall_drain = now()
            chunk_dt = wall_drain - chunk_t0
            emitted = 0
            for slot in list(sched.running):
                if slot in self._parked:
                    continue  # frozen in transfer: emitted PADs only
                req = sched.running[slot]
                if use_spec:
                    # toks_h[slot]: (chunk, 1+draft_k); emitted tokens
                    # form a prefix of each step row
                    for step_row in toks_h[slot]:
                        cnt = 0
                        for t in step_row:
                            if t != PAD_TOKEN:
                                req.generated.append(int(t))
                                cnt += 1
                        if cnt:
                            self.counters["spec_steps"] += 1
                            self.counters["spec_tokens"] += cnt
                            emitted += cnt
                else:
                    for t in toks_h[slot]:
                        if t != PAD_TOKEN:
                            req.generated.append(int(t))
                            emitted += 1
                o = self._req_obs.get(req.rid, {})
                if req.generated and "first" not in o:
                    o["first"] = wall_drain
                    mtr["ttft"].observe(wall_drain - o.get("ready", run_t0))
                finished = bool(done_h[slot])
                if finished:
                    n = len(req.generated)
                    if "first" in o and n > 1:
                        mtr["tpot"].observe(
                            (wall_drain - o["first"]) / (n - 1))
                    mtr["e2e"].observe(wall_drain - o.get("ready", run_t0))
                    mtr["finished"].inc()
                    mtr["generated_tokens"].add(n)
                    self.tracer.end(pid=pid, tid=slot)  # req span
                    sched.complete(slot)
                    if self.paged:
                        if (self.prefix_cache
                                and "positions" not in req.extras):
                            # publish generated pages too: multi-turn
                            # prompts extending this output will hit
                            self.kv.register_prefix(
                                slot, np.concatenate(
                                    [req.prompt,
                                     np.asarray(req.generated, np.int32)]))
                        self.kv.release(slot)
                elif self.paged and self.cfg.sliding_window is not None:
                    # SWA: positions behind pos - window are masked out of
                    # attention; release their pages back to the pool
                    self.counters["pages_trimmed"] += self.kv.trim(
                        slot, int(pos_h[slot]) - self.cfg.sliding_window)
            # chunk-level telemetry: role time split, measured steptrace
            # event, and one X span on the shared "device" lane
            mtr["decode_time"].add(chunk_dt)
            mtr["chunk_hist"].observe(chunk_dt)
            mtr["decode_tokens"].add(emitted)
            self.steptrace.record(
                "spec_decode" if use_spec else "decode", chunk_dt,
                batch=live, steps=self.chunk, tokens=emitted,
                queue_depth=len(sched.waiting))
            self.tracer.complete(
                "decode_chunk", chunk_dt, pid=pid, tid=self._device_tid,
                cat="serve", args={"live": live, "tokens": emitted})
        return {r.rid: np.asarray(r.generated, np.int32)
                for r in sched.finished}

    # ------------------------------------------------------- legacy API

    def generate(self, params, batch: Dict[str, Array], *, max_new: int,
                 temperature: float = 0.0,
                 key: Optional[Array] = None) -> Array:
        """Single-batch generation (old API), served by the new engine.

        Returns (B, max_new) tokens; rows that hit EOS early are padded
        with the EOS id."""
        tokens = np.asarray(batch["tokens"])
        b = tokens.shape[0]
        reqs = []
        for i in range(b):
            req = Request(rid=i, prompt=tokens[i], max_new=max_new)
            # mrope "positions" are (3, B, S): the batch axis is axis 1
            req.extras = {k: (np.asarray(v)[:, i:i + 1]
                              if k == "positions"
                              else np.asarray(v[i:i + 1]))
                          for k, v in batch.items() if k != "tokens"}
            reqs.append(req)
        out = self.run(params, reqs, key=key, temperature=temperature)
        pad = self.eos_id if self.eos_id is not None else 0
        rows = []
        for i in range(b):
            row = out[i]
            if len(row) < max_new:
                row = np.concatenate(
                    [row, np.full(max_new - len(row), pad, np.int32)])
            rows.append(row)
        return jnp.asarray(np.stack(rows))

    def generate_pertoken(self, params, batch: Dict[str, Array], *,
                          max_new: int, temperature: float = 0.0,
                          key: Optional[Array] = None) -> Array:
        """The pre-rebuild per-token loop: one jit dispatch per token.

        Kept as the benchmark baseline and as a cross-check oracle."""
        if not hasattr(self, "_legacy_prefill"):
            cfg, ctx = self.cfg, self.ctx

            def prefill(params, batch):
                return api.prefill_fn(params, batch, cfg, ctx, self.window)

            def decode(params, token, cache):
                return api.decode_fn(params, token, cache, cfg, ctx)

            self._legacy_prefill = jax.jit(prefill)
            self._legacy_decode = jax.jit(decode, donate_argnums=(2,))

        def pick(logits, k):
            last = logits[:, -1, :].astype(jnp.float32)
            if temperature <= 0.0 or k is None:
                return jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
            return jax.random.categorical(
                k, last / temperature, axis=-1)[:, None].astype(jnp.int32)

        logits, cache = self._legacy_prefill(params, batch)
        tokens = []
        tok = pick(logits, key)
        for i in range(max_new):
            tokens.append(tok)
            logits, cache = self._legacy_decode(params, tok, cache)
            key_i = (jax.random.fold_in(key, i + 1)
                     if key is not None else None)
            tok = pick(logits, key_i)
            self.counters["pertoken_steps"] += 1
        return jnp.concatenate(tokens, axis=1)


def quantize_weights(params: Any, dtype=jnp.float8_e4m3fn) -> Any:
    """Weight-only storage quantization (embeddings/norms stay bf16)."""

    def leaf(p: Array) -> Array:
        if p.ndim >= 2 and jnp.issubdtype(p.dtype, jnp.floating):
            return p.astype(dtype)
        return p

    return jax.tree.map(leaf, params)
