"""Batched serving engine: prefill + decode with quantizable caches.

A thin, jit-compiled engine over models/api: prefill a batch of prompts,
then step the decode loop with greedy or temperature sampling. Weight-only
quantization (fp8/int8 storage, bf16 compute) and int8 KV caches are the
Ironwood-era memory levers that let the big assigned archs serve within a
16 GiB/chip pod (see configs/*/SETTINGS).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.config import ModelConfig

Array = jax.Array


@dataclasses.dataclass
class ServeEngine:
    cfg: ModelConfig
    ctx: ModelContext
    window: int

    def __post_init__(self) -> None:
        cfg, ctx = self.cfg, self.ctx

        def prefill(params, batch):
            return api.prefill_fn(params, batch, cfg, ctx, self.window)

        def decode(params, token, cache):
            return api.decode_fn(params, token, cache, cfg, ctx)

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(2,))

    def generate(self, params, batch: Dict[str, Array], *, max_new: int,
                 temperature: float = 0.0,
                 key: Optional[Array] = None) -> Array:
        """Greedy (or sampled) generation. Returns (B, max_new) tokens."""
        logits, cache = self._prefill(params, batch)
        tokens = []
        tok = self._pick(logits, temperature, key, 0)
        for i in range(max_new):
            tokens.append(tok)
            logits, cache = self._decode(params, tok, cache)
            key_i = (jax.random.fold_in(key, i + 1)
                     if key is not None else None)
            tok = self._pick(logits, temperature, key_i, i + 1)
        return jnp.concatenate(tokens, axis=1)

    @staticmethod
    def _pick(logits: Array, temperature: float, key: Optional[Array],
              i: int) -> Array:
        last = logits[:, -1, :].astype(jnp.float32)
        if temperature <= 0.0 or key is None:
            return jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(
            key, last / temperature, axis=-1)[:, None].astype(jnp.int32)


def quantize_weights(params: Any, dtype=jnp.float8_e4m3fn) -> Any:
    """Weight-only storage quantization (embeddings/norms stay bf16)."""

    def leaf(p: Array) -> Array:
        if p.ndim >= 2 and jnp.issubdtype(p.dtype, jnp.floating):
            return p.astype(dtype)
        return p

    return jax.tree.map(leaf, params)
