"""Deterministic fault injection for the serving engine.

The paper's resilience machinery (§Resilience: OCS spare substitution,
FBIST screens, hardware replay) is only testable if faults are
*reproducible*: the chaos harness here draws every fault from a seeded
schedule that is a pure function of the plan — keyed exactly like the
fleet sim's arrival processes (``np.random.default_rng([seed,
crc32(kind)])``), so the fault schedule is byte-identical across
scheduling policies and completely independent of the request traffic.

Four fault kinds, mirroring the production failure modes the engine must
survive:

  * ``worker_fail`` — a prefill worker dies mid-flight; its queued
    prompts re-place onto the least-loaded survivor (the OCS
    spare-substitution analogue, ``PrefillWorkerPool.fail_worker``);
  * ``page_flip`` — silent corruption of a resident KV page (the SDC
    story at serving granularity); detected by per-page CRC32 stamps in
    ``PagedKVCache`` and recovered by quarantine + request replay;
  * ``transfer_drop`` — a disaggregated prefill->decode page handoff is
    lost and retransmitted (the parked slot re-parks);
  * ``straggler`` — a decode chunk takes extra boundaries of wall time
    (work of one chunk, clock of several).

Every recovery path is token-preserving by construction (append-only
pages + position rewind + greedy per-request determinism), which is what
the tier-1 fault-parity gate pins: survivors of an injected schedule
emit byte-identical token streams to the fault-free run.

``startup_bist`` is the serving half of ``core/sdc.FBIST``: golden
patterns through the real Pallas matmul and paged-decode kernels before
a server admits traffic (``launch/serve.py --bist``).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Dict, Optional

import numpy as np

_PICK_RANGE = 1 << 31  # uniform pick draws, reduced mod len() at use


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Per-boundary fault probabilities over a fixed horizon.

    ``seed`` fully determines the schedule; rates are per chunk
    boundary. ``straggler_extra_boundaries`` is the walltime penalty of
    one straggling chunk; ``transfer_retry_boundaries`` is the
    retransmit delay of a dropped page handoff."""

    seed: int = 0
    horizon_boundaries: int = 4096
    worker_fail_rate: float = 0.0
    page_flip_rate: float = 0.0
    transfer_drop_rate: float = 0.0
    straggler_rate: float = 0.0
    straggler_extra_boundaries: int = 1
    transfer_retry_boundaries: int = 2

    def __post_init__(self) -> None:
        for f in ("worker_fail_rate", "page_flip_rate",
                  "transfer_drop_rate", "straggler_rate"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{f} must be in [0, 1], got {v}")
        if self.horizon_boundaries < 1:
            raise ValueError("horizon_boundaries must be >= 1")
        if self.straggler_extra_boundaries < 1 or \
                self.transfer_retry_boundaries < 1:
            raise ValueError("fault delays must be >= 1 boundary")


class FaultInjector:
    """Materialized fault schedule: one (hit mask, pick stream) pair per
    fault kind, drawn eagerly over the plan's horizon from a per-kind
    RNG ``default_rng([seed, crc32(kind)])``.

    Queries are pure reads indexed by boundary number — no internal
    state advances, so the answers cannot depend on traffic, scheduling
    policy, or query order. Past the horizon the schedule is silent."""

    KINDS = ("worker_fail", "page_flip", "transfer_drop", "straggler")

    def __init__(self, plan: FaultPlan) -> None:
        self.plan = plan
        h = plan.horizon_boundaries
        self._hit: Dict[str, np.ndarray] = {}
        self._pick: Dict[str, np.ndarray] = {}
        for kind in self.KINDS:
            rng = np.random.default_rng(
                [plan.seed, zlib.crc32(kind.encode())])
            rate = getattr(plan, f"{kind}_rate")
            self._hit[kind] = rng.random(h) < rate
            self._pick[kind] = rng.integers(0, _PICK_RANGE, h)

    def _event(self, kind: str, boundary: int) -> Optional[int]:
        if not 0 <= boundary < self.plan.horizon_boundaries:
            return None
        if not self._hit[kind][boundary]:
            return None
        return int(self._pick[kind][boundary])

    def worker_failure(self, boundary: int) -> Optional[int]:
        """Uniform pick (reduce mod n_workers) or None."""
        return self._event("worker_fail", boundary)

    def page_flip(self, boundary: int) -> Optional[int]:
        """Uniform pick (reduce mod len(corruptible pages)) or None."""
        return self._event("page_flip", boundary)

    def transfer_drop(self, boundary: int) -> Optional[int]:
        """Uniform pick (reduce mod len(in-flight transfers)) or None."""
        return self._event("transfer_drop", boundary)

    def straggler(self, boundary: int) -> int:
        """Extra boundaries of walltime this chunk pays (0 = on time)."""
        if self._event("straggler", boundary) is None:
            return 0
        return self.plan.straggler_extra_boundaries

    def schedule_digest(self) -> int:
        """CRC32 over the full materialized schedule — the byte-identity
        surface the determinism property tests pin."""
        crc = 0
        for kind in self.KINDS:
            crc = zlib.crc32(self._hit[kind].tobytes(), crc)
            crc = zlib.crc32(self._pick[kind].tobytes(), crc)
        return crc


# ---------------------------------------------------------------------------
# Startup built-in self test (launch/serve.py --bist).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BISTResult:
    passed: bool
    matmul_report: object  # core.sdc.FBISTReport
    paged_decode_ok: bool
    paged_decode_max_err: float


def _paged_decode_check(interpret: bool, tol: float,
                        decode_fn: Optional[Callable] = None
                        ) -> tuple:
    """One golden pattern through the paged-decode kernel vs a float64
    numpy oracle (same independence discipline as FBIST goldens)."""
    import jax.numpy as jnp

    from repro.kernels.decode_attention import paged_decode_attention

    rng = np.random.default_rng(0xB157)
    b, h, kv, d, n, p, m = 2, 4, 2, 16, 9, 8, 4
    q = rng.standard_normal((b, h, d)).astype(np.float32)
    k_pages = rng.standard_normal((n, p, kv, d)).astype(np.float32)
    v_pages = rng.standard_normal((n, p, kv, d)).astype(np.float32)
    table = np.zeros((b, m), np.int32)
    table[0, :3] = (1, 2, 3)
    table[1, :2] = (4, 5)
    pos = np.array([19, 13], np.int32)
    # float64 oracle: gather the pages, masked softmax attention
    groups = h // kv
    golden = np.zeros((b, h, d))
    for bi in range(b):
        keys = k_pages[table[bi]].reshape(m * p, kv, d).astype(np.float64)
        vals = v_pages[table[bi]].reshape(m * p, kv, d).astype(np.float64)
        mask = np.arange(m * p) < pos[bi]
        for hi in range(h):
            g = hi // groups
            s = (keys[:, g] @ q[bi, hi].astype(np.float64)) * d ** -0.5
            s = np.where(mask, s, -np.inf)
            w = np.exp(s - s.max())
            w /= w.sum()
            golden[bi, hi] = w @ vals[:, g]
    fn = decode_fn or (lambda *a: paged_decode_attention(
        a[0], a[1], a[2], a[3], a[4], interpret=interpret))
    got = np.asarray(fn(jnp.asarray(q), jnp.asarray(k_pages),
                        jnp.asarray(v_pages), jnp.asarray(table),
                        jnp.asarray(pos)), np.float64)
    err = float(np.max(np.abs(got - golden)))
    return bool(np.isfinite(err) and err <= tol), err


def startup_bist(*, interpret: bool = True, tol: float = 5e-2,
                 matmul_fn: Optional[Callable] = None,
                 decode_fn: Optional[Callable] = None) -> BISTResult:
    """Serving startup self-test: the FBIST golden patterns through the
    real Pallas matmul kernel, plus one golden paged-decode pattern
    through the paged-attention kernel — both vs independent float64
    numpy oracles. ``interpret=True`` runs the kernels in interpret mode
    (CI / CPU hosts); on TPU pass False to screen the actual hardware.
    ``matmul_fn``/``decode_fn`` exist for fault-injection tests
    (``core.sdc.faulty_wrap``)."""
    from repro.core.sdc import FBIST
    from repro.kernels.matmul import matmul

    mm = matmul_fn or (lambda a, b: matmul(a, b, interpret=interpret))
    report = FBIST(m=128, k=128, n=128, tol=tol).run(mm)
    pd_ok, pd_err = _paged_decode_check(interpret, tol, decode_fn)
    return BISTResult(passed=report.passed and pd_ok,
                      matmul_report=report,
                      paged_decode_ok=pd_ok,
                      paged_decode_max_err=pd_err)
