"""KV-cache backends for the serving engine.

Two memory layouts behind one slot-oriented interface:

``PagedKVCache``
    A vLLM-style block/paged pool for pure-attention decoder-only stacks:
    per layer, k/v live in a shared ``(num_pages, page_size, KV, D)``
    pool; each request owns a list of page ids recorded in its row of the
    device-resident page table. Page 0 is a reserved *trash page*: padded
    table entries point at it, so scatter/gather with padded tables stays
    branch-free on device. The pool dtype is a quantization hook —
    ``int8`` stores per-(token, head) scales alongside the pages (the
    Ironwood int8-KV memory lever; ~2x more resident requests per HBM).

``DenseKVCache``
    Per-slot ring/state caches (the classic layout) for every family —
    attention rings, Mamba conv+ssm state, RWKV token/wkv state,
    encoder-decoder cross-KV. Eviction is O(1): a slot's cache is simply
    overwritten by the next admitted request's prefill.

The host side owns allocation bookkeeping (free page list / free slots);
the device side is pure pytrees threaded through the jitted decode chunk.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.config import ModelConfig

Array = jax.Array
PyTree = Any


def _zeros(spec: PyTree) -> PyTree:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


@dataclasses.dataclass
class PagedKVCache:
    """Host allocator + device page pool. Not jit-traced itself."""

    cfg: ModelConfig
    ctx: ModelContext
    num_pages: int
    page_size: int
    max_batch: int
    max_pages_per_seq: int

    def __post_init__(self) -> None:
        spec = api.paged_state_spec(
            self.cfg, self.num_pages, self.page_size, self.max_batch,
            self.max_pages_per_seq, self.ctx)
        state = _zeros(spec)
        self.pages: PyTree = state["pages"]
        # page 0 is the trash page: never allocated
        self._free: List[int] = list(range(self.num_pages - 1, 0, -1))
        # host mirror of the table; pushed to device on change
        self._table = np.zeros((self.max_batch, self.max_pages_per_seq),
                               np.int32)
        # token-position frontier per slot: page indices < frontier have
        # been allocated at some point (monotonic per lease). Needed
        # because SWA trimming punches holes in the table — ``grow`` must
        # extend past the frontier, never refill trimmed history.
        self._frontier = np.zeros(self.max_batch, np.int64)

    # ---------------------------------------------------------- allocation

    def free_page_count(self) -> int:
        return len(self._free)

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def slot_pages(self, slot: int) -> List[int]:
        return [int(p) for p in self._table[slot] if p != 0]

    def grow(self, slot: int, target_tokens: int) -> bool:
        """Ensure the slot owns pages covering ``target_tokens``; returns
        False (no change) when the pool cannot satisfy the request."""
        have = int(self._frontier[slot])
        need = self.pages_for(target_tokens) - have
        if need <= 0:
            return True
        if need > len(self._free) or have + need > self.max_pages_per_seq:
            return False
        for i in range(need):
            self._table[slot, have + i] = self._free.pop()
        self._frontier[slot] = have + need
        return True

    def trim(self, slot: int, keep_from_token: int) -> int:
        """Free pages that lie wholly behind ``keep_from_token`` (the
        sliding-window lower bound: the attention mask already ignores
        those positions, so only the memory was still held). Their table
        entries become the trash page; the frontier is untouched, so the
        slot keeps appending at its absolute position. Returns the number
        of pages returned to the pool."""
        first_keep = max(0, keep_from_token) // self.page_size
        freed = 0
        for i in range(min(first_keep, int(self._frontier[slot]))):
            page = int(self._table[slot, i])
            if page != 0:
                self._free.append(page)
                self._table[slot, i] = 0
                freed += 1
        return freed

    def release(self, slot: int) -> None:
        self._free.extend(self.slot_pages(slot)[::-1])
        self._table[slot] = 0
        self._frontier[slot] = 0

    def table_device(self) -> Array:
        return jnp.asarray(self._table)

    # ------------------------------------------------------------- device

    def state(self, pos: Array) -> Dict[str, Any]:
        return {"pages": self.pages, "page_table": self.table_device(),
                "pos": pos}

    def write_prefill(self, write_fn, slot: int,
                      prefill_cache: PyTree) -> None:
        """Scatter a single-request dense prefill cache into this slot's
        pages via the jitted ``write_fn`` (built by the engine). Table
        entries beyond the slot's allocation are 0, so the padded tail of
        the prefill lands in the trash page."""
        row = jnp.asarray(self._table[slot])
        self.pages = write_fn(self.pages, prefill_cache, row)


@dataclasses.dataclass
class DenseKVCache:
    """Per-slot dense ring/state caches for any model family."""

    cfg: ModelConfig
    ctx: ModelContext
    window: int
    max_batch: int

    def __post_init__(self) -> None:
        spec = api.cache_spec(self.cfg, self.max_batch, self.window,
                              self.ctx)
        self.cache: PyTree = _zeros(spec)

    def state(self, pos: Array) -> Dict[str, Any]:
        cache = dict(self.cache)
        cache["pos"] = pos
        return cache

    def update(self, cache: PyTree) -> None:
        self.cache = {k: v for k, v in cache.items() if k != "pos"}

    def write_prefill(self, write_fn, slot: int,
                      prefill_cache: PyTree) -> None:
        """Copy a 1-request prefill cache into batch row ``slot``."""
        self.cache = write_fn(self.cache, prefill_cache, jnp.int32(slot))
