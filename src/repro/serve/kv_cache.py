"""KV-cache backends for the serving engine.

Two memory layouts behind one slot-oriented interface:

``PagedKVCache``
    A vLLM-style block/paged pool for pure-attention decoder-only stacks:
    per layer, k/v live in a shared ``(num_pages, page_size, KV, D)``
    pool; each request owns a list of page ids recorded in its row of the
    device-resident page table. Page 0 is a reserved *trash page*: padded
    table entries point at it, so scatter/gather with padded tables stays
    branch-free on device. The pool dtype is a quantization hook —
    ``int8`` stores per-(token, head) bf16 scales in page-aligned scale
    pages ``(N, P, KV)`` that stream through the same page table as the
    KV pages, so the Pallas kernels dequantize in VMEM (the Ironwood
    int8-KV memory lever; ~2x more resident requests per HBM, gated at
    >= 1.5x in bench_serve).

    On top of the pool sits **prefix caching** (serving millions of users
    means most traffic shares prompt prefixes — system prompts, few-shot
    templates):

      * every page is reference-counted; ``adopt_prefix`` maps cached
        pages into a new request's table row without copying (share),
        ``fork`` gives a slot a private copy when a write would touch a
        shared or published page (copy-on-write);
      * full prompt pages are content-addressed in a global index — the
        chain hash of page *i* folds the hash of page *i-1* with the
        page's tokens, so a hit certifies the entire prefix, not just one
        block;
      * pages whose refcount drops to zero but whose content is indexed
        stay resident as an LRU pool: allocation prefers the free list
        and evicts least-recently-used cached pages only under pressure.

    The lifecycle (see docs/serving.md for the full diagram)::

        lookup_prefix -> adopt_prefix -> grow -> [suffix prefill]
             -> register_prefix -> decode ... -> release
                                    (refcount 0 + indexed => LRU cached)

``DenseKVCache``
    Per-slot ring/state caches (the classic layout) for every family —
    attention rings, Mamba conv+ssm state, RWKV token/wkv state,
    encoder-decoder cross-KV. Eviction is O(1): a slot's cache is simply
    overwritten by the next admitted request's prefill.

The host side owns allocation bookkeeping (free page list / refcounts /
prefix index); the device side is pure pytrees threaded through the
jitted decode chunk.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.blocks import CACHE_LOGICAL, PAGE_LOGICAL, ModelContext
from repro.models.config import ModelConfig
from repro.sharding.axes import AxisRules, logical_sharding

Array = jax.Array
PyTree = Any

_CHAIN_SEED = 0xA5A5A5A5


def _zeros(spec: PyTree) -> PyTree:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def _place_named_tree(tree: PyTree, logical_of, mesh, rules: AxisRules,
                      dropped) -> PyTree:
    """device_put every leaf of a {name: array} tree (nested dicts ok)
    onto ``mesh`` per its logical axes; a leading extra dim (stacking over
    blocks/layers) is treated as replicated. Appends divisibility
    fallbacks to ``dropped``."""
    def walk(node):
        if isinstance(node, dict):
            return {k: walk_named(k, v) if not isinstance(v, dict)
                    else walk(v) for k, v in node.items()}
        return node

    def walk_named(key, arr):
        logical = logical_of(key) or (None,) * arr.ndim
        if len(arr.shape) == len(logical) + 1:  # stacked over blocks
            logical = (None, *logical)
        sh = logical_sharding(logical, arr.shape, mesh, rules, dropped)
        return jax.device_put(arr, sh)

    return walk(tree)


@dataclasses.dataclass
class PagedKVCache:
    """Host allocator + device page pool. Not jit-traced itself."""

    cfg: ModelConfig
    ctx: ModelContext
    num_pages: int
    page_size: int
    max_batch: int
    max_pages_per_seq: int
    # serving mesh: when set, the page pool (and int8 scale pages) are
    # laid out sharded on the KV-head axis over "model" per ``rules``,
    # while ALL host bookkeeping (table / refcounts / prefix index /
    # frontier) stays replicated — prefix caching, CoW, and speculation
    # never see the mesh. Divisibility fallbacks (GQA KV replication)
    # are appended to ``dropped`` for the engine's one-time report.
    mesh: Any = None
    rules: Optional[AxisRules] = None
    dropped: Optional[List[Tuple[str, int]]] = None

    def __post_init__(self) -> None:
        spec = api.paged_state_spec(
            self.cfg, self.num_pages, self.page_size, self.max_batch,
            self.max_pages_per_seq, self.ctx)
        state = _zeros(spec)
        self.pages: PyTree = state["pages"]
        self._repl = None
        if self.mesh is not None:
            self.pages = _place_named_tree(
                self.pages, PAGE_LOGICAL.get, self.mesh, self.rules,
                self.dropped)
            self._repl = jax.sharding.NamedSharding(
                self.mesh, jax.sharding.PartitionSpec())
        # page 0 is the trash page: never allocated
        self._free: List[int] = list(range(self.num_pages - 1, 0, -1))
        # host mirror of the table; pushed to device on change
        self._table = np.zeros((self.max_batch, self.max_pages_per_seq),
                               np.int32)
        # token-position frontier per slot: page indices < frontier have
        # been allocated at some point (monotonic per lease). Needed
        # because SWA trimming punches holes in the table — ``grow`` must
        # extend past the frontier, never refill trimmed history.
        self._frontier = np.zeros(self.max_batch, np.int64)
        # prefix-cache bookkeeping ------------------------------------
        # _ref[p]: live table references to page p (sharers)
        self._ref = np.zeros(self.num_pages, np.int32)
        # _index: chain hash -> (page id, block tokens). The tokens are
        # kept so a hit is verified against the actual block — the chain
        # hash alone is a fast 64-bit filter, not a proof of identity.
        # _published: page id -> chain hash for every page whose content
        # is in the index (whether a slot still references it or not).
        # _evictable: insertion-ordered {pid: None} of published pages
        # with refcount 0 — LRU order, O(1) evict/peek (re-inserted on
        # every recency refresh, so dict order == recency order).
        self._index: Dict[int, Tuple[int, Tuple[int, ...]]] = {}
        self._published: Dict[int, int] = {}
        self._evictable: Dict[int, None] = {}
        # integrity bookkeeping (the checkpoint manager's CRC trick
        # applied to live pages): _page_crc stamps a published page's
        # device bytes at publish time — published pages are immutable
        # (writes past the full-page prefix, CoW forks before any other
        # write), so a later mismatch is silent corruption, not a race.
        # _quarantined chain hashes are barred from the index forever:
        # a poisoned prefix can never be re-adopted or re-published.
        # Stamping costs a device fetch per published page, so it is off
        # unless the engine runs with a FaultInjector (or the caller
        # opts in) — the fault-free path stays byte- and perf-identical.
        self.integrity_checks = False
        self._page_crc: Dict[int, int] = {}
        self._quarantined: Set[int] = set()
        self.counters = {"prefix_lookups": 0, "prefix_hit_tokens": 0,
                         "pages_shared": 0, "pages_forked": 0,
                         "pages_evicted": 0, "pages_published": 0,
                         "pages_allocated": 0, "pages_quarantined": 0}

    # ---------------------------------------------------------- allocation

    def free_page_count(self) -> int:
        """Pages allocatable right now (free list + evictable cached)."""
        return len(self._free) + len(self._evictable)

    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def slot_pages(self, slot: int) -> List[int]:
        return [int(p) for p in self._table[slot] if p != 0]

    def _evict_lru(self) -> Optional[int]:
        """Reclaim the least-recently-used cached page nobody references."""
        if not self._evictable:
            return None
        pid = next(iter(self._evictable))  # oldest recency
        self._unpublish(pid)
        self.counters["pages_evicted"] += 1
        return pid

    def _unpublish(self, pid: int) -> None:
        h = self._published.pop(pid)
        entry = self._index.get(h)
        if entry is not None and entry[0] == pid:
            del self._index[h]
        self._evictable.pop(pid, None)
        self._page_crc.pop(pid, None)

    def _touch(self, pid: int) -> None:
        """Move an evictable page to the most-recently-used end."""
        if pid in self._evictable:
            del self._evictable[pid]
            self._evictable[pid] = None

    def _alloc_page(self) -> Optional[int]:
        pid = self._free.pop() if self._free else self._evict_lru()
        if pid is not None:
            self._ref[pid] = 1
            self.counters["pages_allocated"] += 1
        return pid

    def _drop_ref(self, pid: int) -> None:
        self._ref[pid] -= 1
        assert self._ref[pid] >= 0
        if self._ref[pid] == 0:
            if pid in self._published:
                # content stays cached; becomes LRU-evictable
                self._evictable[pid] = None
            else:
                self._free.append(pid)

    def grow(self, slot: int, target_tokens: int) -> bool:
        """Ensure the slot owns pages covering ``target_tokens``; returns
        False (no change) when the pool cannot satisfy the request."""
        have = int(self._frontier[slot])
        need = self.pages_for(target_tokens) - have
        if need <= 0:
            return True
        if (need > self.free_page_count()
                or have + need > self.max_pages_per_seq):
            return False
        for i in range(need):
            self._table[slot, have + i] = self._alloc_page()
        self._frontier[slot] = have + need
        return True

    def trim(self, slot: int, keep_from_token: int) -> int:
        """Release pages that lie wholly behind ``keep_from_token`` (the
        sliding-window lower bound: the attention mask already ignores
        those positions, so only the memory was still held). Their table
        entries become the trash page; the frontier is untouched, so the
        slot keeps appending at its absolute position. Shared pages just
        drop a reference; published ones stay cached. Returns the number
        of references released."""
        first_keep = max(0, keep_from_token) // self.page_size
        freed = 0
        for i in range(min(first_keep, int(self._frontier[slot]))):
            page = int(self._table[slot, i])
            if page != 0:
                self._drop_ref(page)
                self._table[slot, i] = 0
                freed += 1
        return freed

    def release(self, slot: int) -> None:
        for pid in self.slot_pages(slot)[::-1]:
            self._drop_ref(pid)
        self._table[slot] = 0
        self._frontier[slot] = 0

    def table_device(self) -> Array:
        if self._repl is not None:  # host table broadcast to every shard
            return jax.device_put(jnp.asarray(self._table), self._repl)
        return jnp.asarray(self._table)

    def table_row(self, slot: int) -> Array:
        """The slot's page-table row as a (1, M) device array (the batch
        view a single-request span prefill expects)."""
        row = jnp.asarray(self._table[slot:slot + 1])
        return (row if self._repl is None
                else jax.device_put(row, self._repl))

    # ------------------------------------------------------- prefix cache

    def _prefix_blocks(self, tokens: np.ndarray
                       ) -> List[Tuple[int, Tuple[int, ...]]]:
        """(chain hash, block tokens) for every *full* page of ``tokens``:
        hash i folds hash i-1 with page i's tokens, so equal hash is a
        whole-prefix filter (lookups still verify the block tokens)."""
        n_full = len(tokens) // self.page_size
        out: List[Tuple[int, Tuple[int, ...]]] = []
        h = _CHAIN_SEED
        for i in range(n_full):
            blk = tuple(int(t) for t in
                        tokens[i * self.page_size:(i + 1) * self.page_size])
            h = hash((h,) + blk)
            out.append((h, blk))
        return out

    def prefix_hashes(self, tokens: np.ndarray) -> List[int]:
        return [h for h, _ in self._prefix_blocks(tokens)]

    def lookup_prefix(self, tokens: np.ndarray) -> Tuple[int, List[int]]:
        """Longest indexed chain covering a *strict* prefix of ``tokens``
        (at least one token is always left to prefill — its logits seed
        decoding). Hits are verified against the stored block tokens (a
        64-bit chain-hash collision must not serve another prompt's KV)
        and refreshed to most-recently-used. Returns (cached token
        count, page ids)."""
        self.counters["prefix_lookups"] += 1
        pids: List[int] = []
        for h, blk in self._prefix_blocks(tokens):
            entry = self._index.get(h)
            if entry is None or entry[1] != blk:
                break
            pids.append(entry[0])
        while pids and len(pids) * self.page_size >= len(tokens):
            pids.pop()
        for pid in pids:
            self._touch(pid)
        cached = len(pids) * self.page_size
        self.counters["prefix_hit_tokens"] += cached
        return cached, pids

    def adopt_prefix(self, slot: int, pids: List[int]) -> None:
        """Map cached pages into an empty slot's table row (share: no
        copy, refcount only)."""
        assert int(self._frontier[slot]) == 0 and not self.slot_pages(slot)
        for i, pid in enumerate(pids):
            self._table[slot, i] = pid
            self._ref[pid] += 1
            self._evictable.pop(pid, None)  # referenced again
        self._frontier[slot] = len(pids)
        self.counters["pages_shared"] += len(pids)

    def abort_adoption(self, slot: int, cached: int,
                       pids: List[int]) -> None:
        """Roll back a lookup_prefix + adopt_prefix pair when admission
        fails afterwards (page pressure): the slot's references are
        released and the counter bumps reversed, so the retry at the
        next chunk boundary doesn't double-count hit metrics."""
        self.release(slot)
        self.counters["prefix_lookups"] -= 1
        self.counters["prefix_hit_tokens"] -= cached
        self.counters["pages_shared"] -= len(pids)

    def register_prefix(self, slot: int, tokens: np.ndarray) -> int:
        """Publish the slot's full-page prefix KV into the global index.
        Stops at the first table hole (SWA trim breaks the chain) or at
        a quarantined chain hash (every later hash folds the poisoned
        one, so the whole tail stays out of the index). Pages already
        indexed (e.g. adopted ones) are left canonical. Returns the
        number of newly published pages."""
        n = 0
        for i, (h, blk) in enumerate(self._prefix_blocks(tokens)):
            pid = int(self._table[slot, i])
            if pid == 0 or h in self._quarantined:
                break
            if h in self._index:
                continue  # identical content already published
            self._published[pid] = h
            self._index[h] = (pid, blk)
            if self.integrity_checks:
                self._page_crc[pid] = self._page_bytes_crc(pid)
            n += 1
        self.counters["pages_published"] += n
        return n

    def fork(self, slot: int, page_idx: int, copy_fn) -> bool:
        """Copy-on-write: replace ``table[slot, page_idx]`` with a private
        copy of the page (device copy via the engine-built jitted
        ``copy_fn(pages, src, dst)``). Returns False when no page can be
        allocated."""
        src = int(self._table[slot, page_idx])
        assert src != 0
        new = self._alloc_page()
        if new is None:
            return False
        self.pages = copy_fn(self.pages, jnp.int32(src), jnp.int32(new))
        self._table[slot, page_idx] = new
        self._drop_ref(src)
        self.counters["pages_forked"] += 1
        return True

    def ensure_private(self, slot: int, from_token: int, copy_fn) -> bool:
        """CoW guard before a write phase: fork any shared or published
        page covering positions >= ``from_token``. A no-op in the normal
        flow (cached prefixes are page-aligned and writes start past
        them), but it makes the write path safe by construction."""
        first = max(0, from_token) // self.page_size
        for i in range(first, int(self._frontier[slot])):
            pid = int(self._table[slot, i])
            if pid == 0:
                continue
            if self._ref[pid] > 1 or pid in self._published:
                if not self.fork(slot, i, copy_fn):
                    return False
        return True

    # ---------------------------------------------------------- integrity

    def _page_bytes_crc(self, pid: int) -> int:
        """CRC32 over the page's device bytes across every pool leaf
        (k, v, and int8 scale pages), in deterministic pytree order."""
        crc = 0
        for leaf in jax.device_get(
                [leaf[:, pid] for leaf in jax.tree.leaves(self.pages)]):
            crc = zlib.crc32(np.ascontiguousarray(leaf).tobytes(), crc)
        return crc

    def corruptible_pages(self) -> List[int]:
        """Stamped published pages, sorted — the fault injector's victim
        pool (deterministic target selection by drawn rank)."""
        return sorted(self._page_crc)

    def corrupt_page(self, pid: int) -> None:
        """Flip the page's content in place (fault injection): every
        element changes (x -> 1 - x for x <= 0, else -x), including int8
        pools, so a CRC stamp cannot collide with the corrupted bytes."""
        self.pages = jax.tree.map(
            lambda arr: arr.at[:, pid].set(
                jnp.where(arr[:, pid] <= 0, 1 - arr[:, pid],
                          -arr[:, pid])),
            self.pages)

    def verify_integrity(self) -> List[Tuple[int, int]]:
        """Re-hash every stamped page and quarantine mismatches: the
        chain hash is barred from the index permanently, the page is
        unpublished (free-listed immediately when nobody references it),
        and callers fail/replay any slot still referencing it. Returns
        the detected (page id, chain hash) pairs."""
        bad: List[Tuple[int, int]] = []
        for pid, crc in list(self._page_crc.items()):
            if self._page_bytes_crc(pid) == crc:
                continue
            h = self._published[pid]
            self._quarantined.add(h)
            self._unpublish(pid)
            if self._ref[pid] == 0:
                self._free.append(pid)
            self.counters["pages_quarantined"] += 1
            bad.append((pid, h))
        return bad

    def slots_referencing(self, pid: int) -> List[int]:
        """Slots whose table row still maps the page (the blast radius
        of a quarantined page: each must be failed and replayed)."""
        return [s for s in range(self.max_batch)
                if pid in self._table[s]]

    # ------------------------------------------------------------- device

    def state(self, pos: Array) -> Dict[str, Any]:
        return {"pages": self.pages, "page_table": self.table_device(),
                "pos": pos}

    # ---------------------------------------------------------- accounting

    def per_token_bytes(self) -> int:
        """HBM bytes held per cached token across all layers (k + v pages
        plus int8 scale pages when quantized) — the decode roofline's
        bytes/token term, and the denominator of resident-batch capacity."""
        total = sum(leaf.dtype.itemsize * leaf.size
                    for leaf in jax.tree.leaves(self.pages))
        return total // (self.num_pages * self.page_size)

    def dedup_stats(self) -> Dict[str, int]:
        """Cross-request prefix-cache dedup accounting: every shared page
        reference is one page of prefill compute AND one page of HBM that
        was never spent. ``pages_unique`` counts every pool allocation in
        the measurement window (prompt, decode headroom, CoW forks) —
        callers bounding a window zero both counters first (bench_serve
        does before its timed run)."""
        shared = int(self.counters["pages_shared"])
        unique = int(self.counters["pages_allocated"])
        return {"pages_shared": shared, "pages_unique": unique,
                "bytes_saved": shared * self.page_size *
                self.per_token_bytes()}


@dataclasses.dataclass
class DenseKVCache:
    """Per-slot dense ring/state caches for any model family."""

    cfg: ModelConfig
    ctx: ModelContext
    window: int
    max_batch: int
    mesh: Any = None
    rules: Optional[AxisRules] = None
    dropped: Optional[List[Tuple[str, int]]] = None

    def __post_init__(self) -> None:
        spec = api.cache_spec(self.cfg, self.max_batch, self.window,
                              self.ctx)
        self.cache: PyTree = _zeros(spec)
        if self.mesh is not None:
            # batch rows over "data", KV heads over "model" (same logical
            # table as training checkpoints use; see blocks.CACHE_LOGICAL)
            self.cache = _place_named_tree(
                self.cache, CACHE_LOGICAL.get, self.mesh, self.rules,
                self.dropped)

    def state(self, pos: Array) -> Dict[str, Any]:
        cache = dict(self.cache)
        cache["pos"] = pos
        return cache

    def update(self, cache: PyTree) -> None:
        self.cache = {k: v for k, v in cache.items() if k != "pos"}

    def write_prefill(self, write_fn, slot: int,
                      prefill_cache: PyTree) -> None:
        """Copy a 1-request prefill cache into batch row ``slot``."""
        self.cache = write_fn(self.cache, prefill_cache, jnp.int32(slot))
