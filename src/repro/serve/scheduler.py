"""Request-level continuous-batching scheduler.

The serving engine decodes in fixed device-resident chunks; between
chunks this scheduler owns every request-level decision:

  * **admission** — arrived requests claim free batch slots (and pages,
    in paged mode) in arrival order;
  * **completion** — finished slots (EOS or token budget) are drained and
    freed mid-stream, so the batch refills without draining;
  * **preemption** — under page pressure the youngest running request is
    evicted: its page references are dropped and it re-queues with its
    generated prefix folded into the prompt. Resumption is *not* a full
    recompute anymore: the victim's prompt pages were published to the
    prefix index at admission, so (while they stay cached) re-admission
    adopts them and prefills only the generated suffix — with greedy
    sampling the resumed request reproduces the same tokens, which is
    what the parity test pins.

The scheduler is pure host-side bookkeeping — everything it decides is
reflected to the device as page-table/pos updates before the next chunk.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival`` is in engine-step units (the
    benchmark's synthetic trace clock); 0 = available immediately."""

    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    arrival: int = 0

    def __post_init__(self) -> None:
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")
        self.generated: List[int] = []
        # waiting | prefilling | running | finished | failed | shed
        self.state = "waiting"
        self.slot: int = -1
        self.preemptions = 0
        # fault-replay bookkeeping: ``retries`` counts re-admissions
        # after an injected/detected fault; ``not_before`` is the
        # exponential-backoff floor (engine-step clock) before the next
        # admission attempt.
        self.retries = 0
        self.not_before = 0
        # disaggregated mode: True once a prefill worker finished this
        # request's prompt (it may enter decode admission); reset on
        # preemption — the released pages must be re-prefilled.
        self.prefill_done = False
        # tokens served from the prefix cache at the latest admission
        # (set by the engine; the prefill computed only the suffix)
        self.cached_prefix_len = 0
        self.extras: Dict[str, np.ndarray] = {}  # e.g. enc_feats (1, S, D)

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.generated)

    def resume_prompt(self) -> np.ndarray:
        """Prompt for (re-)prefill: original + everything generated."""
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])


class ContinuousBatchingScheduler:
    def __init__(self, max_slots: int, aged_priority_after: int = 2):
        self.max_slots = max_slots
        # a request preempted/replayed this many times jumps ahead of
        # fresh arrivals at admission (starvation guard: under sustained
        # pressure the youngest-first eviction policy would otherwise
        # keep evicting the same re-queued request forever)
        self.aged_priority_after = aged_priority_after
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}  # slot -> request
        self.finished: List[Request] = []
        self.failed: List[Request] = []
        self.shed: List[Request] = []
        self.stats = {"admissions": 0, "preemptions": 0, "completions": 0,
                      "replays": 0, "failures": 0, "shed": 0}
        self._occupancy: List[float] = []

    # ------------------------------------------------------------- queues

    def add(self, req: Request) -> None:
        self.waiting.append(req)
        self.waiting.sort(key=lambda r: (r.arrival, r.rid))

    def has_work(self) -> bool:
        return bool(self.waiting) or bool(self.running)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.max_slots) if s not in self.running]

    def _aged(self, req: Request) -> bool:
        return (req.preemptions + req.retries) >= self.aged_priority_after

    def next_admittable(self, clock: int) -> Optional[Request]:
        """Oldest eligible request, except that *aged* requests (over
        the preemption/retry threshold) outrank fresh arrivals — the
        deterministic anti-starvation rule. ``not_before`` (fault-replay
        backoff) gates eligibility exactly like ``arrival``."""
        best: Optional[Request] = None
        for req in self.waiting:
            if req.arrival > clock or req.not_before > clock:
                continue
            if best is None or ((not self._aged(best), best.arrival,
                                 best.rid) >
                                (not self._aged(req), req.arrival,
                                 req.rid)):
                best = req
        return best

    def admit(self, req: Request, slot: int) -> None:
        self.waiting.remove(req)
        req.state, req.slot = "running", slot
        self.running[slot] = req
        self.stats["admissions"] += 1

    def complete(self, slot: int) -> Request:
        req = self.running.pop(slot)
        req.state, req.slot = "finished", -1
        self.finished.append(req)
        self.stats["completions"] += 1
        return req

    def preempt_victim(self) -> Optional[Request]:
        """Youngest running request (latest arrival, then highest rid) —
        the classic recompute-preemption policy: the oldest requests keep
        their progress."""
        if not self.running:
            return None
        return max(self.running.values(), key=lambda r: (r.arrival, r.rid))

    def preempt(self, req: Request) -> None:
        assert req.state == "running"
        del self.running[req.slot]
        req.state, req.slot = "waiting", -1
        req.preemptions += 1
        req.prefill_done = False  # pages dropped: must re-prefill
        self.stats["preemptions"] += 1
        self.add(req)

    def requeue(self, req: Request, *, not_before: int = 0) -> None:
        """Fault replay: like ``preempt`` but accounted separately and
        gated by an exponential-backoff floor. The generated prefix is
        kept — re-admission re-prefills ``resume_prompt()`` (adopting
        any surviving cached pages) and continues token-identically."""
        assert req.state == "running"
        del self.running[req.slot]
        req.state, req.slot = "waiting", -1
        req.retries += 1
        req.prefill_done = False
        req.not_before = not_before
        self.stats["replays"] += 1
        self.add(req)

    def fail(self, req: Request) -> None:
        """Deterministic terminal failure (retry budget exhausted): the
        request leaves the system with ``state="failed"`` instead of
        looping through replay forever."""
        if req.state == "running":
            del self.running[req.slot]
        elif req in self.waiting:
            self.waiting.remove(req)
        req.state, req.slot = "failed", -1
        self.failed.append(req)
        self.stats["failures"] += 1

    def shed_request(self, req: Request) -> None:
        """Admission-control shed: dropped from the waiting queue before
        consuming any decode resources (state="shed")."""
        self.waiting.remove(req)
        req.state, req.slot = "shed", -1
        self.shed.append(req)
        self.stats["shed"] += 1

    # -------------------------------------------------------------- stats

    def record_occupancy(self, live: int) -> None:
        self._occupancy.append(live / max(self.max_slots, 1))

    @property
    def mean_occupancy(self) -> float:
        return float(np.mean(self._occupancy)) if self._occupancy else 0.0


class PrefillWorkerPool:
    """Dedicated prefill workers for prefill/decode disaggregation.

    Cold prompts are placed on the shallowest worker queue; each worker
    chunk-prefills its queue in FIFO order at one span per engine chunk
    (the same chunked-prefill cadence the co-located engine uses), so a
    prompt of S tokens occupies its worker for ``ceil(S / span_len)``
    chunk boundaries. ``pop_ready`` releases finished prompts back to
    the decode scheduler; the engine then models the page transfer
    (ICI/DCN) before the decode slot goes live.

    Purely host-side queueing — the actual prefill compute still runs
    through the engine's span-prefill program at admission; this pool
    models *when* that work happened on the prefill workers and keeps
    per-role queue-depth statistics.
    """

    def __init__(self, n_workers: int, span_len: int, chunk: int):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        self.n_workers = n_workers
        self.span_len = max(span_len, 1)
        self.chunk = max(chunk, 1)
        # per-worker FIFO of (ready_at_clock, request)
        self.queues: List[List[tuple]] = [[] for _ in range(n_workers)]
        self.free_at = [0] * n_workers
        self.stats = {"placed": 0, "prefilled_tokens": 0,
                      "worker_failures": 0, "failover_replacements": 0}

    def _place_on(self, w: int, req: Request, clock: int) -> int:
        n_tok = len(req.resume_prompt())
        dur = -(-n_tok // self.span_len) * self.chunk  # ceil spans * chunk
        start = max(clock, self.free_at[w])
        ready = start + dur
        self.free_at[w] = ready
        self.queues[w].append((ready, req))
        req.state = "prefilling"
        self.stats["placed"] += 1
        self.stats["prefilled_tokens"] += n_tok
        return ready

    def place(self, req: Request, clock: int) -> int:
        """Queue ``req`` on the least-loaded worker; returns ready time."""
        w = min(range(self.n_workers),
                key=lambda i: (len(self.queues[i]), self.free_at[i], i))
        return self._place_on(w, req, clock)

    def fail_worker(self, w: int, clock: int, *,
                    respawn_boundaries: int = 4) -> List[Request]:
        """Kill worker ``w`` mid-flight: its queued prompts (including
        the one being prefilled) are re-placed on the least-loaded
        *survivor* — the OCS spare-substitution analogue: route around
        the failed component and replay the lost work. The dead worker
        respawns (becomes placeable again) after ``respawn_boundaries``
        chunks; with one worker total, the replays simply wait for the
        respawn. Returns the re-placed requests."""
        lost = [req for _, req in self.queues[w]]
        self.queues[w] = []
        self.free_at[w] = clock + respawn_boundaries * self.chunk
        self.stats["worker_failures"] += 1
        survivors = [i for i in range(self.n_workers) if i != w]
        for req in lost:
            if survivors:
                tgt = min(survivors,
                          key=lambda i: (len(self.queues[i]),
                                         self.free_at[i], i))
            else:
                tgt = w  # sole worker: replay lands after the respawn
            self._place_on(tgt, req, clock)
            self.stats["failover_replacements"] += 1
        return lost

    def pop_ready(self, clock: int) -> List[Request]:
        """Prompts whose prefill completed by ``clock`` (FIFO per worker)."""
        out: List[Request] = []
        for q in self.queues:
            while q and q[0][0] <= clock:
                _, req = q.pop(0)
                req.prefill_done = True
                req.state = "waiting"
                out.append(req)
        return out

    def pending(self) -> bool:
        return any(self.queues)

    def depths(self) -> List[int]:
        return [len(q) for q in self.queues]
