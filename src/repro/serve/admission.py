"""SLO-aware admission control for the serving engine.

Graceful degradation instead of queue collapse: when the engine cannot
meet a request's TTFT deadline even under best-case scheduling, serving
it anyway burns decode slots on guaranteed SLO violations and pushes the
*next* request over its deadline too. The controller sheds such requests
at enqueue time (deterministically — the decision is a pure function of
the engine clock and the request, so runs are replayable) and drops
speculative decoding under queue pressure (speculation trades decode
FLOPs for latency; under a deep queue the FLOPs are better spent on
plain chunks — and dropping speculation is token-identical by
construction, so the policy is purely a latency/throughput trade).

The same two policies exist at fleet scale in ``fleet/serve_jobs.py``
(``shed_policy="ttft"``) so scenario suites can score shedding against
head-of-line blocking on ``slo_goodput``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Knobs for enqueue-time shedding and pressure degradation.

    ``ttft_deadline_steps``: shed a request when its best-case first
    token would land more than this many engine steps after arrival
    (None disables shedding). ``spec_off_queue_depth``: run plain decode
    chunks instead of speculative ones while more than this many
    requests wait (None keeps speculation unconditionally)."""

    ttft_deadline_steps: Optional[int] = None
    spec_off_queue_depth: Optional[int] = None

    def __post_init__(self) -> None:
        if self.ttft_deadline_steps is not None \
                and self.ttft_deadline_steps < 1:
            raise ValueError("ttft_deadline_steps must be >= 1")
        if self.spec_off_queue_depth is not None \
                and self.spec_off_queue_depth < 0:
            raise ValueError("spec_off_queue_depth must be >= 0")


@dataclasses.dataclass
class AdmissionController:
    """Stateless policy evaluator (counters live in the engine's
    ``fault_stats`` so they flow through the obs CATALOG)."""

    policy: AdmissionPolicy = dataclasses.field(
        default_factory=AdmissionPolicy)

    def predicted_ttft_steps(self, req, clock: int, *, chunk: int,
                             span_len: int, disaggregated: bool) -> int:
        """Best-case TTFT in engine steps: the wait already accrued,
        plus the prefill spans still owed (disaggregated prefill pays
        one chunk of boundaries per span; co-located prefill completes
        within the admitting boundary), plus the chunk that drains the
        first decode token."""
        wait = max(0, clock - req.arrival)
        owed = len(req.prompt) - req.cached_prefix_len
        if req.prefill_done or owed <= 0:
            prefill = 0
        elif disaggregated:
            prefill = -(-owed // span_len) * chunk
        else:
            prefill = chunk
        return wait + prefill + chunk

    def should_shed(self, req, clock: int, *, chunk: int, span_len: int,
                    disaggregated: bool) -> bool:
        """True when even the best-case first token misses the deadline.
        Requests with sunk work are never shed: past prefill, already
        generating (preemption/fault replay), or in retry backoff —
        shedding those would discard completed compute, and a replayed
        request's accrued wait says nothing about its viability."""
        ddl = self.policy.ttft_deadline_steps
        if ddl is None or req.prefill_done or req.generated \
                or req.retries or req.preemptions:
            return False
        est = self.predicted_ttft_steps(
            req, clock, chunk=chunk, span_len=span_len,
            disaggregated=disaggregated)
        return est > ddl

    def drop_speculation(self, queue_depth: int) -> bool:
        """True when queue pressure says to spend decode FLOPs on plain
        chunks this boundary (token-identical degradation)."""
        depth = self.policy.spec_off_queue_depth
        return depth is not None and queue_depth > depth
