"""Deterministic data pipeline.

Paper §Resilience point 4: "Strict deterministic repeatability requirements:
to aid in system testing and failure detection." The pipeline here is a
pure function of (seed, step): restarting from a checkpoint at step k
replays exactly the batches k, k+1, ... — no iterator state to persist, no
drift between replicas. The same property drives the determinism tests and
lets the failure-injection benchmark verify bit-identical losses across a
kill/restore cycle.

Sources: a synthetic token stream (hashed counter -> vocab) used by tests
and benchmarks, and a binary token-file source (memory-mapped, sharded by
host) for real corpora. Both produce next-token-prediction batches with
labels shifted by one.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    token_file: Optional[str] = None  # None -> synthetic


def _philox_tokens(seed: int, step: int, batch: int, seq: int,
                   vocab: int) -> np.ndarray:
    """Counter-based deterministic tokens: f(seed, step) with no state."""
    rng = np.random.Generator(
        np.random.Philox(key=seed, counter=[0, 0, 0, step]))
    # skew towards low ids like a zipfian corpus (cheap approximation)
    u = rng.random((batch, seq + 1))
    toks = np.floor((u ** 3.0) * vocab).astype(np.int32)
    return np.minimum(toks, vocab - 1)


class TokenFileSource:
    """Memory-mapped int32 token file; step-indexed deterministic slices."""

    def __init__(self, path: str, cfg: DataConfig):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.cfg = cfg
        need = cfg.global_batch * (cfg.seq_len + 1)
        if len(self.tokens) < need:
            raise ValueError(
                f"token file too small: {len(self.tokens)} < {need}")

    def batch_at(self, step: int) -> np.ndarray:
        cfg = self.cfg
        span = cfg.global_batch * (cfg.seq_len + 1)
        n_spans = len(self.tokens) // span
        rng = np.random.Generator(
            np.random.Philox(key=cfg.seed, counter=[0, 0, 0, step]))
        start = int(rng.integers(0, n_spans)) * span
        flat = np.asarray(self.tokens[start:start + span])
        return flat.reshape(cfg.global_batch, cfg.seq_len + 1)


class DataPipeline:
    """Step-indexed batches; ``batch_for_step(k)`` is pure in (seed, k)."""

    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.source = (TokenFileSource(cfg.token_file, cfg)
                       if cfg.token_file else None)

    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        if self.source is not None:
            toks = self.source.batch_at(step)
        else:
            toks = _philox_tokens(cfg.seed, step, cfg.global_batch,
                                  cfg.seq_len, cfg.vocab_size)
        batch = {"tokens": toks[:, :-1].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        mc = self.model_cfg
        if mc is not None and mc.is_encoder_decoder:
            rng = np.random.Generator(
                np.random.Philox(key=cfg.seed + 1, counter=[0, 0, 0, step]))
            batch["enc_feats"] = rng.standard_normal(
                (cfg.global_batch, mc.encoder_seq, mc.d_model),
                dtype=np.float32) * 0.1
        if mc is not None and mc.pos_emb == "mrope":
            pos = np.broadcast_to(np.arange(cfg.seq_len, dtype=np.int32),
                                  (cfg.global_batch, cfg.seq_len))
            batch["positions"] = np.stack([pos, pos, pos])
        return batch

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_for_step(step)
            step += 1
