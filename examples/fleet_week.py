"""A week on an Ironwood pod: four 2K-chip jobs, 16 spare cubes,
stochastic host failures, SDC screens, OCS reconfigurations — the
paper's fleet story end to end, with a Chrome trace you can load in
chrome://tracing or ui.perfetto.dev.

  PYTHONPATH=src python examples/fleet_week.py \
      [--days 7] [--trace /tmp/fleet_week_trace.json]
"""

from __future__ import annotations

import argparse

from repro.core import hwspec
from repro.core.sdc import SDCRateModel
from repro.fleet import FleetConfig, FleetSimulator, JobSpec, PowerModel


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=float, default=7.0)
    ap.add_argument("--trace", default="/tmp/fleet_week_trace.json")
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args()

    cfg = FleetConfig(
        tpu="ironwood", total_cubes=144, host_mtbf_hours=2000.0,
        repair_hours=4.0, detect_s=30.0, restore_s=120.0,
        sdc=SDCRateModel(rate_per_chip_hour=2e-6, screen_interval_s=600.0,
                         screen_coverage=0.8),
        seed=args.seed)
    jobs = [JobSpec(name=f"job{i}", chips=2048, total_steps=10**9,
                    step_time_s=1.0, checkpoint_every_steps=600)
            for i in range(4)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(args.days * 86400.0)

    print(f"=== {args.days:g} simulated days on an Ironwood pod "
          f"(144 cubes, 4 x 2048-chip jobs, 16 spares) ===")
    fs = sim.fleet_summary()
    print("fleet:", {k: round(v, 4) for k, v in fs.items()})
    pm = PowerModel(hwspec.get(cfg.tpu))
    for name, job in sim.jobs.items():
        s = job.ledger.summary()
        p = pm.job_summary(job.ledger, job.spec.chips)
        print(f"  {name}: goodput={s['goodput']:.4f} "
              f"steps={job.base_step} "
              f"rework={s['rework_s']:.0f}s restore={s['restore_s']:.0f}s "
              f"energy={p['energy_kwh']:.0f}kWh "
              f"gCO2e/EFLOP={p.get('gco2e_per_eflop', float('nan')):.1f}")
    sim.trace.write(args.trace)
    print(f"chrome trace ({len(sim.trace.events)} events) -> {args.trace}")


if __name__ == "__main__":
    main()
