"""A week on an Ironwood pod: four 2K-chip jobs, 16 spare cubes,
stochastic host failures, SDC screens, OCS reconfigurations, elastic
re-scale when spares run out, synchronous checkpoint writes contending
for the shared filer, and roofline-fed step times — the paper's fleet
story end to end, with a Chrome trace you can load in chrome://tracing
or ui.perfetto.dev.

  PYTHONPATH=src python examples/fleet_week.py \
      [--days 7] [--trace /tmp/fleet_week_trace.json] \
      [--scale-policy shrink|queue] [--ckpt-write-s 0] [--roofline]
"""

from __future__ import annotations

import argparse

from repro.core import hwspec
from repro.core.sdc import SDCRateModel
from repro.fleet import (FleetConfig, FleetSimulator, JobSpec, PowerModel,
                         TrainWorkload, job_spec_from_roofline)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=float, default=7.0)
    ap.add_argument("--trace", default="/tmp/fleet_week_trace.json")
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--scale-policy", choices=("queue", "shrink"),
                    default="shrink",
                    help="what starvation does: queue for repairs, or "
                         "re-scale to a smaller slice (paper arm)")
    ap.add_argument("--ckpt-write-s", type=float, default=0.0,
                    help="synchronous checkpoint write stall; co-located "
                         "writers contend for shared bandwidth (0=async)")
    ap.add_argument("--roofline", action="store_true",
                    help="price step times from the roofline "
                         "(fleet.perf) instead of the 1 s constant")
    args = ap.parse_args()

    cfg = FleetConfig(
        tpu="ironwood", total_cubes=144, host_mtbf_hours=2000.0,
        repair_hours=4.0, detect_s=30.0, restore_s=120.0,
        ckpt_write_s=args.ckpt_write_s,
        sdc=SDCRateModel(rate_per_chip_hour=2e-6, screen_interval_s=600.0,
                         screen_coverage=0.8),
        seed=args.seed)
    if args.roofline:
        # a 70B dense model at a 16M-token global batch; the elastic arm
        # follows the Ironwood scaling curve when it shrinks
        wl = TrainWorkload(n_params=70e9, tokens_per_step=4096 * 4096)
        jobs = [job_spec_from_roofline(
            f"job{i}", "ironwood", wl, chips=2048, total_steps=10**9,
            checkpoint_every_steps=600, scale_policy=args.scale_policy,
            min_cubes=8) for i in range(4)]
    else:
        jobs = [JobSpec(name=f"job{i}", chips=2048, total_steps=10**9,
                        step_time_s=1.0, checkpoint_every_steps=600,
                        scale_policy=args.scale_policy, min_cubes=8)
                for i in range(4)]
    sim = FleetSimulator(cfg, jobs)
    sim.run(args.days * 86400.0)

    print(f"=== {args.days:g} simulated days on an Ironwood pod "
          f"(144 cubes, 4 x 2048-chip jobs, 16 spares, "
          f"policy={args.scale_policy}) ===")
    fs = sim.fleet_summary()
    print("fleet:", {k: round(v, 4) for k, v in fs.items()})
    pm = PowerModel(hwspec.get(cfg.tpu))
    for name, job in sim.jobs.items():
        s = job.ledger.summary()
        p = pm.job_summary(job.ledger, job.spec.chips)
        # rework steps are the sim's replayed_steps: same reading as the
        # real trainer's replay ledger in launch/train.py output
        replayed = sum(e.steps for e in job.ledger.events
                       if e.kind == "rework")
        print(f"  {name}: goodput={s['goodput']:.4f} "
              f"steps={job.base_step} replayed_steps={replayed} "
              f"rescales={job.rescales} grow_backs={job.grow_backs} "
              f"cubes={job.cubes}/{job.spec.full_cubes} "
              f"step_time={job.step_time_s:.2f}s "
              f"energy={p['energy_kwh']:.0f}kWh "
              f"gCO2e/EFLOP={p.get('gco2e_per_eflop', float('nan')):.1f}")
    sim.trace.write(args.trace)
    print(f"chrome trace ({len(sim.trace.events)} events) -> {args.trace}")


if __name__ == "__main__":
    main()
