"""Quickstart: train a ~100M-param dense LM for a few hundred steps on CPU.

This is the end-to-end driver deliverable: real config, deterministic data
pipeline, AdamW + cosine schedule, async checkpointing, goodput + carbon
ledgers — the full framework path at laptop scale.

  PYTHONPATH=src python examples/quickstart.py [--steps 300]
"""

import argparse
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core.cci import CCI_BY_NAME, CarbonLedger
from repro.launch.train import build_trainer
from repro.models.config import ModelConfig

# ~100M params: 12L, d=512, 8H, kv=4, ff=2048, 32k vocab
CONFIG_100M = ModelConfig(
    name="quickstart-100m", family="dense",
    n_layers=12, d_model=512, n_heads=8, n_kv_heads=4, d_ff=2048,
    vocab_size=32768, head_dim=64,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    print(f"model: {CONFIG_100M.total_params()/1e6:.1f}M params")
    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer, state = build_trainer(
            CONFIG_100M, batch=args.batch, seq=args.seq, ckpt_dir=ckpt_dir,
            microbatches=2, checkpoint_every=50,
            compute_dtype=jnp.bfloat16)
        carbon = CarbonLedger(CCI_BY_NAME["ironwood"])
        t0 = time.time()
        state, ledger, losses = trainer.run(state, args.steps)
        wall = time.time() - t0
        tokens = args.batch * args.seq * len(losses)
        carbon.record_step(6.0 * CONFIG_100M.total_params() * tokens)
        print(f"\n{len(losses)} steps, {wall:.0f}s, "
              f"{tokens/wall:.0f} tok/s")
        print(f"loss: {losses[0]:.3f} -> {min(losses):.3f}")
        print("goodput:", round(ledger.goodput, 4))
        print("emissions if run on an Ironwood pod:",
              f"{carbon.grams_co2e:.2e} gCO2e")
        assert losses[-1] < losses[0], "loss did not improve"


if __name__ == "__main__":
    main()
