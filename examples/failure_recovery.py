"""Failure recovery demo: kill cubes mid-training, watch the OCS scheduler
substitute spares, restore from checkpoint, and verify the loss trajectory
is bit-identical to an uninterrupted run (the paper's resilience contract:
checkpoint/restore + deterministic repeatability + modular isolation).

  PYTHONPATH=src python examples/failure_recovery.py
"""

import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs.registry import get_smoke
from repro.launch.train import build_trainer

STEPS = 30


def run(failures, ckpt_dir):
    cfg = get_smoke("internlm2_1_8b")
    trainer, state = build_trainer(
        cfg, batch=4, seq=64, ckpt_dir=ckpt_dir, checkpoint_every=8,
        failures=failures)
    state, ledger, losses = trainer.run(state, STEPS)
    return losses, ledger


def main() -> None:
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        print("running clean baseline ...")
        losses_clean, ledger_clean = run({}, d1)
        print("running with cube failures at steps 11 and 23 ...")
        losses_fail, ledger_fail = run({11: 5, 23: 40}, d2)

    identical = losses_clean == losses_fail
    print(f"\nloss trajectories identical: {identical}")
    print(f"clean   goodput: {ledger_clean.goodput:.4f}")
    s = ledger_fail.summary()
    print(f"failure goodput: {s['goodput']:.4f} "
          f"(rework {s['rework_s']:.2f}s, restore {s['restore_s']:.2f}s, "
          f"detect {s['detect_s']:.2f}s)")
    assert identical, "recovery must reproduce the exact trajectory"
    print("OK: failures recovered with exact-replay semantics")


if __name__ == "__main__":
    main()
