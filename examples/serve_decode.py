"""Batched serving demo over three model families (dense GQA via the
paged KV engine, SWA MoE via paged + fp8 weights, attention-free RWKV via
the dense-slot engine) — the Ironwood serving recipe at smoke scale, now
running the continuous-batching engine's device-resident decode loop.

  PYTHONPATH=src python examples/serve_decode.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke
from repro.models import api
from repro.models.blocks import ModelContext
from repro.models.params import init_params
from repro.serve.engine import ServeEngine, quantize_weights


def main() -> None:
    rng = np.random.default_rng(0)
    for arch, quant in [("qwen2_5_3b", None),
                        ("mixtral_8x22b", jnp.float8_e4m3fn),
                        ("rwkv6_1_6b", None)]:
        cfg = get_smoke(arch)
        ctx = ModelContext(compute_dtype=jnp.float32, q_chunk=512,
                           mamba_chunk=16, rwkv_chunk=8)
        params = init_params(jax.random.key(0), api.model_specs(cfg))
        if quant is not None:
            params = quantize_weights(params, quant)
        engine = ServeEngine(cfg, ctx, window=48, max_batch=4, chunk=8)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (4, 16)), jnp.int32)}
        t0 = time.time()
        out = engine.generate(params, batch, max_new=24,
                              temperature=0.8, key=jax.random.key(7))
        dt = time.time() - t0
        q = "fp8 weights" if quant is not None else "fp32 weights"
        mode = "paged" if engine.paged else "dense"
        print(f"{arch:18s} [{q:12s}|{mode:5s}] 4x24 tokens in {dt:5.1f}s "
              f"({4 * 24 / dt:6.1f} tok/s, "
              f"{engine.counters['host_syncs']} host syncs) "
              f"sample={np.asarray(out[0])[:6]}")


if __name__ == "__main__":
    main()
