"""DLRM-style recommendation model on the SparseCore-analogue embedding
path — the workload SparseCore was built for (61% of TPU v1's 2016 mix).

Multi-table embedding bags (the Pallas sparse_gather kernel pattern) feed a
dense MLP tower; trained end-to-end on synthetic click data. Embedding
tables are the vocab-sharded, all-to-all-gathered tensors on a real pod.

  PYTHONPATH=src python examples/dlrm_sparsecore.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

TABLES = {"user": (5000, 32), "item": (20000, 32), "cat": (200, 16)}
BAG = 4
MLP = [32 + 32 + 16, 64, 32, 1]


def init(key):
    params = {}
    for i, (name, (v, d)) in enumerate(TABLES.items()):
        params[f"emb_{name}"] = jax.random.normal(
            jax.random.fold_in(key, i), (v, d)) * 0.05
    dims = MLP
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = jax.random.normal(
            jax.random.fold_in(key, 10 + i), (a, b)) * (a ** -0.5)
        params[f"b{i}"] = jnp.zeros((b,))
    return params


def forward(params, batch):
    feats = []
    for name in TABLES:
        bag = ops.sparse_gather_sum(
            params[f"emb_{name}"], batch[f"idx_{name}"],
            batch[f"w_{name}"], impl="ref")  # swap impl="pallas" on TPU
        feats.append(bag)
    x = jnp.concatenate(feats, axis=-1)
    n = len(MLP) - 1
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x[:, 0]


def loss_fn(params, batch):
    logits = forward(params, batch)
    y = batch["label"]
    return jnp.mean(jnp.maximum(logits, 0) - logits * y +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))


def make_batch(key, n=256):
    ks = jax.random.split(key, 8)
    batch = {}
    for i, (name, (v, _)) in enumerate(TABLES.items()):
        batch[f"idx_{name}"] = jax.random.randint(ks[i], (n, BAG), 0, v)
        batch[f"w_{name}"] = jnp.ones((n, BAG)) / BAG
    # label correlated with user embedding bucket parity (learnable signal)
    batch["label"] = (batch["idx_user"].sum(-1) % 2).astype(jnp.float32)
    return batch


def main() -> None:
    params = init(jax.random.key(0))
    step = jax.jit(lambda p, b: jax.tree.map(
        lambda x, g: x - 0.05 * g, p,
        jax.grad(loss_fn)(p, b)))
    losses = []
    t0 = time.time()
    for i in range(120):
        batch = make_batch(jax.random.key(100 + i))
        losses.append(float(loss_fn(params, batch)))
        params = step(params, batch)
    print(f"DLRM embedding-bag training: loss {losses[0]:.4f} -> "
          f"{losses[-1]:.4f} in {time.time()-t0:.1f}s")
    assert losses[-1] < losses[0]
    print("OK: SparseCore-path (gather/scatter) model trains")


if __name__ == "__main__":
    main()
